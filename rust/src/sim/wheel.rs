//! Bucketed time-wheel for per-SM event scheduling.
//!
//! Replaces the `BinaryHeap<Reverse<(t, wid, kind)>>` the SM used through
//! PR 5. The wheel files events into `SLOTS` per-cycle buckets covering a
//! sliding window `[base, base + SLOTS)`; events beyond the window sit in
//! an overflow list and are refiled when the window rotates. Push is O(1),
//! and the idle-hint query walks a 16-word occupancy bitmap instead of
//! maintaining heap order on every insert.
//!
//! Determinism contract (what the backend-equivalence oracle leans on):
//!
//! * [`EventWheel::pop_due`] yields events in exactly the order the old
//!   heap produced — ascending `(t, wid, payload)` — including events
//!   pushed for the cycle currently being drained (the `MemArrive` →
//!   `PrefetchDone` chains), which are re-merged into the sorted due list
//!   before the next pop.
//! * The wheel's evolution is a function of the *push/pop sequence* only,
//!   never of which intermediate cycles a driver happened to poll at:
//!   polls at cycles with nothing due advance the cursor and rotate the
//!   window exactly as a single coarse poll would (`rollovers` counts one
//!   per window rotation performed while events are pending, and the
//!   empty-wheel realignment does not count). The
//!   `rollovers_are_partition_invariant` test pins this, which is what
//!   makes `Stats::event_wheel_rollovers` bit-identical across backends
//!   that poll the same SM at different cycle subsets.
//!
//! Lateness bound: an event may be pushed at most one cycle in the past
//! (`t + 1 >= cursor`, checked in debug builds) — the commit phase posts
//! replies for the cycle that just stepped. Late events are filed at the
//! cursor but keep their real timestamp, so they still sort (and pop)
//! ahead of the current cycle's natives, exactly as the heap ordered them.

/// Window width in cycles. Covers the common event horizon (ALU/SFU
/// latencies, L1/LLC hits, one DRAM round trip at moderate latency
/// factors); longer-latency events take the overflow path.
pub const SLOTS: usize = 1024;
const SLOT_MASK: u64 = SLOTS as u64 - 1;
const OCC_WORDS: usize = SLOTS / 64;

/// A sliding-window event queue with deterministic heap-order drain.
#[derive(Clone, Debug)]
pub struct EventWheel<E> {
    buckets: Vec<Vec<(u64, usize, E)>>,
    /// One bit per slot: bucket non-empty. The idle-hint scan and the
    /// cursor advance walk words, not buckets.
    occ: [u64; OCC_WORDS],
    /// Events at or beyond `base + SLOTS`, refiled on rotation.
    overflow: Vec<(u64, usize, E)>,
    /// Exact min timestamp across `overflow` (`u64::MAX` when empty).
    overflow_min: u64,
    /// Window start; always slot-aligned (`base % SLOTS == 0`).
    base: u64,
    /// Next cycle not yet fully drained; `base <= cursor <= base + SLOTS`.
    cursor: u64,
    len: usize,
    /// Min pending timestamp. Exact whenever it exceeds the last drained
    /// cycle (pops can only strand it at already-drained times, which the
    /// hint query detects and repairs by an exact bitmap rescan).
    min_cache: u64,
    /// Window rotations performed while events were pending.
    rollovers: u64,
    /// Sorted (descending) scratch holding the remainder of the cycle
    /// currently being drained; popped from the back.
    due: Vec<(u64, usize, E)>,
}

impl<E: Copy + Ord + std::fmt::Debug> Default for EventWheel<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E: Copy + Ord + std::fmt::Debug> EventWheel<E> {
    pub fn new() -> Self {
        EventWheel {
            buckets: (0..SLOTS).map(|_| Vec::new()).collect(),
            occ: [0; OCC_WORDS],
            overflow: Vec::new(),
            overflow_min: u64::MAX,
            base: 0,
            cursor: 0,
            len: 0,
            min_cache: u64::MAX,
            rollovers: 0,
            due: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Schedule `(t, wid, e)`. Events up to one cycle in the past are
    /// accepted (commit-phase replies for the cycle that just stepped) and
    /// drain immediately on the next poll.
    pub fn push(&mut self, t: u64, wid: usize, e: E) {
        debug_assert!(
            t + 1 >= self.cursor,
            "event at {t} scheduled before drained cycle {}",
            self.cursor
        );
        self.file((t, wid, e));
        self.len += 1;
        self.min_cache = self.min_cache.min(t);
    }

    /// File an entry at its effective cycle `max(t, cursor)` — bucket if
    /// inside the window, overflow otherwise. Keeps the real timestamp so
    /// drain order matches the heap's.
    fn file(&mut self, entry: (u64, usize, E)) {
        let eff = entry.0.max(self.cursor);
        if eff >= self.base + SLOTS as u64 {
            self.overflow_min = self.overflow_min.min(entry.0);
            self.overflow.push(entry);
        } else {
            // `base` is aligned and `base <= eff < base + SLOTS`, so the
            // masked value is exactly `eff - base`.
            let slot = (eff & SLOT_MASK) as usize;
            self.buckets[slot].push(entry);
            self.occ[slot >> 6] |= 1u64 << (slot & 63);
        }
    }

    /// Pop the next event with `t <= now`, in ascending `(t, wid, e)`
    /// order. Draining past the window rotates it; draining an empty
    /// wheel realigns the window without counting a rotation.
    pub fn pop_due(&mut self, now: u64) -> Option<(u64, usize, E)> {
        loop {
            // Merge arrivals for the cycle being drained (same-cycle
            // chained pushes land in the cursor's bucket) into the sorted
            // due scratch.
            if self.cursor <= now && self.cursor < self.base + SLOTS as u64 {
                let slot = (self.cursor & SLOT_MASK) as usize;
                if self.occ[slot >> 6] & (1u64 << (slot & 63)) != 0 {
                    self.due.append(&mut self.buckets[slot]);
                    self.occ[slot >> 6] &= !(1u64 << (slot & 63));
                    self.due.sort_unstable_by(|a, b| b.cmp(a));
                }
            }
            if let Some(ev) = self.due.pop() {
                self.len -= 1;
                return Some(ev);
            }
            if self.cursor > now {
                return None;
            }
            if self.len == 0 {
                // Nothing pending anywhere: skip the window forward in one
                // move. Not a rotation — no event's filing is affected, so
                // the rollover counter stays backend-invariant.
                self.cursor = now + 1;
                self.base = self.cursor & !SLOT_MASK;
                return None;
            }
            // Advance the cursor to the next occupied cycle <= now,
            // rotating the window as often as needed to get there.
            loop {
                let window_end = self.base + SLOTS as u64;
                let limit = (now + 1).min(window_end);
                let from = (self.cursor - self.base) as usize;
                let upto = (limit - self.base) as usize;
                if let Some(slot) = self.first_occupied_in(from, upto) {
                    self.cursor = self.base + slot as u64;
                    break;
                }
                if limit == now + 1 {
                    self.cursor = now + 1;
                    return None;
                }
                self.rotate();
            }
        }
    }

    /// Advance the window one width and refile overflow events that now
    /// fall inside it. Only called with events pending, so each rotation
    /// is forced by the push/pop sequence itself — any driver polling the
    /// same sequence performs the same rotations.
    fn rotate(&mut self) {
        debug_assert!(self.occ.iter().all(|&w| w == 0), "rotating a window with live buckets");
        self.base += SLOTS as u64;
        self.cursor = self.base;
        self.rollovers += 1;
        if self.overflow_min >= self.base + SLOTS as u64 {
            return;
        }
        let pending = std::mem::take(&mut self.overflow);
        self.overflow_min = u64::MAX;
        for entry in pending {
            self.file(entry);
        }
    }

    /// First occupied slot index in `[from, upto)`, via the bitmap.
    fn first_occupied_in(&self, from: usize, upto: usize) -> Option<usize> {
        if from >= upto {
            return None;
        }
        let mut word = from >> 6;
        let last_word = (upto - 1) >> 6;
        let mut bits = self.occ[word] & (!0u64 << (from & 63));
        loop {
            if bits != 0 {
                let slot = (word << 6) + bits.trailing_zeros() as usize;
                return if slot < upto { Some(slot) } else { None };
            }
            word += 1;
            if word > last_word {
                return None;
            }
            bits = self.occ[word];
        }
    }

    /// Min pending timestamp (`u64::MAX` when empty) — the idle
    /// skip-ahead hint, identical to what `heap.peek()` returned. Exact:
    /// a cached min at an already-drained cycle is repaired by a bitmap
    /// rescan before being reported.
    pub fn next_event_hint(&mut self, now: u64) -> u64 {
        debug_assert!(self.due.is_empty(), "hint queried mid-drain");
        if self.len == 0 {
            return u64::MAX;
        }
        if self.min_cache > now {
            return self.min_cache;
        }
        let mut min = self.overflow_min;
        let from = (self.cursor.max(self.base) - self.base) as usize;
        if let Some(slot) = self.first_occupied_in(from, SLOTS) {
            // The <=1-cycle lateness bound means no later slot can hold a
            // smaller timestamp than this bucket's min.
            let bucket_min =
                self.buckets[slot].iter().map(|&(t, _, _)| t).min().expect("occupied slot");
            min = min.min(bucket_min);
        }
        self.min_cache = min;
        min
    }

    /// Drain the rotation counter (folded into `Stats` by the SM).
    pub fn take_rollovers(&mut self) -> u64 {
        std::mem::take(&mut self.rollovers)
    }

    /// Append every pending event to `out`, sorted ascending `(t, wid, e)`
    /// — the replay engine's entry-state fingerprint of the wheel. Walks
    /// only occupied slots (via the bitmap) plus the overflow list. Must
    /// not be called mid-drain; the fingerprint is taken after the
    /// boundary poll's `drain_events`, where the due scratch is empty.
    pub fn collect_pending(&self, out: &mut Vec<(u64, usize, E)>) {
        debug_assert!(self.due.is_empty(), "pending events collected mid-drain");
        out.clear();
        out.reserve(self.len);
        for w in 0..OCC_WORDS {
            let mut bits = self.occ[w];
            while bits != 0 {
                let slot = (w << 6) | bits.trailing_zeros() as usize;
                out.extend_from_slice(&self.buckets[slot]);
                bits &= bits - 1;
            }
        }
        out.extend_from_slice(&self.overflow);
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Drain everything due at `now` from both the wheel and a reference
    /// heap, asserting identical sequences.
    fn drain_both(
        wheel: &mut EventWheel<u8>,
        heap: &mut BinaryHeap<Reverse<(u64, usize, u8)>>,
        now: u64,
    ) -> usize {
        let mut popped = 0;
        loop {
            let expect = match heap.peek() {
                Some(&Reverse(ev)) if ev.0 <= now => {
                    heap.pop();
                    Some(ev)
                }
                _ => None,
            };
            let got = wheel.pop_due(now);
            assert_eq!(got, expect, "drain divergence at now={now}");
            if got.is_none() {
                return popped;
            }
            popped += 1;
        }
    }

    /// Differential test against the exact heap the wheel replaces:
    /// random pushes (spanning the window and the overflow path) drained
    /// at random strides must yield identical pop order and identical
    /// idle hints.
    #[test]
    fn matches_binary_heap_order_and_hints() {
        prop::check(32, 0xEE1_0001, |rng: &mut Xoshiro256| {
            let mut wheel = EventWheel::new();
            let mut heap: BinaryHeap<Reverse<(u64, usize, u8)>> = BinaryHeap::new();
            let mut now = 0u64;
            for _ in 0..200 {
                for _ in 0..rng.below(6) {
                    // Mix short, window-edge, and deep-overflow horizons.
                    let dt = match rng.below(3) {
                        0 => rng.below(30),
                        1 => 900 + rng.below(300),
                        _ => 2000 + rng.below(4000),
                    };
                    let t = now + 1 + dt;
                    let wid = rng.below(8) as usize;
                    let payload = rng.below(4) as u8;
                    wheel.push(t, wid, payload);
                    heap.push(Reverse((t, wid, payload)));
                }
                now += 1 + rng.below(700);
                drain_both(&mut wheel, &mut heap, now);
                assert_eq!(
                    wheel.next_event_hint(now),
                    heap.peek().map(|&Reverse((t, _, _))| t).unwrap_or(u64::MAX),
                    "hint divergence at now={now}"
                );
                assert_eq!(wheel.len(), heap.len());
            }
        });
    }

    /// The rollover count must depend only on the push/pop sequence, not
    /// on which intermediate cycles the driver polled at — the property
    /// that makes `event_wheel_rollovers` identical between the reference
    /// backend (polls every global stop) and the parallel backend (polls
    /// only at hint cycles).
    #[test]
    fn rollovers_are_partition_invariant() {
        prop::check(16, 0xEE1_0002, |rng: &mut Xoshiro256| {
            // Script: at each logical step, some pushes then a drain time.
            let mut script: Vec<(Vec<(u64, usize, u8)>, u64)> = Vec::new();
            let mut t0 = 0u64;
            for _ in 0..40 {
                t0 += 1 + rng.below(1500);
                let pushes = (0..rng.below(4))
                    .map(|_| (t0 + 1 + rng.below(5000), rng.below(8) as usize, rng.below(4) as u8))
                    .collect();
                script.push((pushes, t0));
            }
            let run = |dense: bool| {
                let mut wheel = EventWheel::new();
                let mut pops = Vec::new();
                let mut last = 0u64;
                for (pushes, t) in &script {
                    if dense {
                        // Poll every cycle between script points.
                        for c in last..*t {
                            while let Some(ev) = wheel.pop_due(c) {
                                pops.push(ev);
                            }
                        }
                    }
                    last = *t;
                    while let Some(ev) = wheel.pop_due(*t) {
                        pops.push(ev);
                    }
                    for &(t, wid, p) in pushes {
                        wheel.push(t, wid, p);
                    }
                }
                // Flush the tail so every pushed event pops.
                while let Some(ev) = wheel.pop_due(u64::MAX - 1) {
                    pops.push(ev);
                }
                (pops, wheel.take_rollovers())
            };
            let (coarse_pops, coarse_rolls) = run(false);
            let (dense_pops, dense_rolls) = run(true);
            assert_eq!(coarse_pops, dense_pops);
            assert_eq!(coarse_rolls, dense_rolls, "rollovers must not depend on poll points");
        });
    }

    /// Same-cycle chained pushes (the MemArrive → PrefetchDone pattern)
    /// and one-cycle-late pushes drain in heap order.
    #[test]
    fn same_cycle_and_late_pushes_drain_in_heap_order() {
        let mut w = EventWheel::new();
        w.push(10, 3, 1u8);
        w.push(10, 1, 0u8);
        assert_eq!(w.pop_due(10), Some((10, 1, 0)));
        // Chained push for the cycle being drained.
        w.push(10, 2, 9u8);
        // Late push (commit reply for the cycle that just stepped): keeps
        // its timestamp, so it sorts ahead of the cycle-10 natives.
        w.push(9, 7, 5u8);
        assert_eq!(w.pop_due(10), Some((9, 7, 5)));
        assert_eq!(w.pop_due(10), Some((10, 2, 9)));
        assert_eq!(w.pop_due(10), Some((10, 3, 1)));
        assert_eq!(w.pop_due(10), None);
        assert!(w.is_empty());
    }

    /// Empty-wheel realignment is free; rotations with pending events are
    /// counted once per window crossed.
    #[test]
    fn empty_realign_is_not_a_rollover() {
        let mut w: EventWheel<u8> = EventWheel::new();
        assert_eq!(w.pop_due(1_000_000), None);
        assert_eq!(w.take_rollovers(), 0, "empty skip must not count");
        w.push(1_000_000 + 3 * SLOTS as u64 + 5, 0, 0);
        assert_eq!(
            w.pop_due(1_000_000 + 4 * SLOTS as u64),
            Some((1_000_000 + 3 * SLOTS as u64 + 5, 0, 0))
        );
        assert!(w.take_rollovers() >= 3, "crossing windows with a pending event must count");
    }

    /// `collect_pending` must see every event — bucketed and overflow —
    /// in sorted order, without disturbing the wheel.
    #[test]
    fn collect_pending_is_sorted_and_complete() {
        let mut w: EventWheel<u8> = EventWheel::new();
        let far = 5 * SLOTS as u64 + 7;
        w.push(far, 1, 2); // overflow path
        w.push(12, 3, 1);
        w.push(12, 0, 0);
        w.push(900, 2, 3);
        let mut out = Vec::new();
        w.collect_pending(&mut out);
        assert_eq!(out, vec![(12, 0, 0), (12, 3, 1), (900, 2, 3), (far, 1, 2)]);
        assert_eq!(w.len(), 4, "collection must not consume events");
        assert_eq!(w.pop_due(12), Some((12, 0, 0)));
    }

    /// Hints see overflow events (nothing in the window must not read as
    /// "no events").
    #[test]
    fn hint_covers_overflow() {
        let mut w: EventWheel<u8> = EventWheel::new();
        let far = 10 * SLOTS as u64;
        w.push(far, 0, 0);
        assert_eq!(w.next_event_hint(0), far);
        assert_eq!(w.pop_due(far - 1), None);
        assert_eq!(w.next_event_hint(far - 1), far);
        assert_eq!(w.pop_due(far), Some((far, 0, 0)));
    }
}
