//! Multi-SM driver: lockstep SM simulation over a shared memory system,
//! with global skip-ahead when no SM can make progress this cycle.

use super::config::SimConfig;
use super::memsys::SharedMem;
use super::sm::SmSim;
use super::stats::Stats;
use crate::compiler::{compile, CompileOptions, CompiledKernel};
use crate::workloads::gen;
use crate::workloads::WorkloadSpec;

/// Run a compiled kernel under `cfg`. Resident warp count follows the MRF
/// capacity (TLP — §2.1); all SMs run the same kernel on staggered data.
pub fn run(ck: &CompiledKernel, cfg: &SimConfig) -> Stats {
    let resident = cfg.resident_warps(ck.kernel.num_regs);
    let mut shared = SharedMem::new(cfg.mem);
    let mut sms: Vec<SmSim> = (0..cfg.num_sms).map(|s| SmSim::new(cfg, ck, resident, s)).collect();

    let mut now: u64 = 0;
    loop {
        let mut next = u64::MAX;
        let mut all_done = true;
        for sm in &mut sms {
            let hint = sm.step(now, &mut shared);
            next = next.min(hint);
            all_done &= sm.done();
        }
        if all_done || now >= cfg.max_cycles {
            break;
        }
        now = if next == u64::MAX { now + 1 } else { next.max(now + 1) };
    }

    // Per-SM counters (including the L1 memory counters, which SmSim folds
    // into its own Stats at the access sites) aggregate via plain merges.
    let mut total = Stats::default();
    for sm in &sms {
        total.merge(&sm.stats);
    }
    total.cycles = now;
    total.llc_hits = shared.llc_hits;
    total.llc_misses = shared.llc_misses;
    total
}

/// Compile options matching a simulator configuration.
pub fn compile_options(cfg: &SimConfig, renumber: bool) -> CompileOptions {
    CompileOptions {
        max_regs_per_interval: cfg.regs_per_interval,
        num_banks: cfg.mrf_banks,
        renumber,
        mode: cfg.hierarchy.subgraph_mode(),
        bank_map: cfg.bank_map,
    }
}

/// Build + compile + simulate one workload. `renumber` selects LTRF_conf
/// when the hierarchy is LTRF.
pub fn run_workload(spec: &WorkloadSpec, cfg: &SimConfig, renumber: bool) -> Stats {
    let kernel = gen::build(spec);
    let ck = compile(&kernel, compile_options(cfg, renumber));
    run(&ck, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::HierarchyKind;
    use crate::workloads::suite;

    fn quick_cfg(kind: HierarchyKind) -> SimConfig {
        SimConfig { max_cycles: 5_000_000, ..SimConfig::with_hierarchy(kind) }.normalize_capacity()
    }

    #[test]
    fn workload_runs_to_completion_bl_and_ltrf() {
        let spec = suite::workload_by_name("kmeans").unwrap();
        for kind in [HierarchyKind::Baseline, HierarchyKind::Ltrf { plus: false }] {
            let st = run_workload(spec, &quick_cfg(kind), false);
            assert!(st.warps_finished > 0, "{}", kind.name());
            assert!(st.cycles < 5_000_000, "{} hit the cycle cap", kind.name());
        }
    }

    #[test]
    fn register_sensitive_workload_gains_tlp_from_bigger_rf() {
        let spec = suite::workload_by_name("cfd").unwrap();
        let small = quick_cfg(HierarchyKind::Ltrf { plus: false });
        let big = SimConfig { warp_regs_capacity: 16384, ..small };
        assert!(
            big.resident_warps(spec.regs_per_thread())
                > small.resident_warps(spec.regs_per_thread())
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let spec = suite::workload_by_name("hotspot").unwrap();
        let cfg = quick_cfg(HierarchyKind::Ltrf { plus: false });
        let a = run_workload(spec, &cfg, false);
        let b = run_workload(spec, &cfg, false);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.instructions, b.instructions);
    }

    #[test]
    fn multi_sm_scales_instruction_count() {
        let spec = suite::workload_by_name("lud").unwrap();
        let one = quick_cfg(HierarchyKind::Baseline);
        let two = SimConfig { num_sms: 2, ..one };
        let s1 = run_workload(spec, &one, false);
        let s2 = run_workload(spec, &two, false);
        assert!(
            (s2.instructions as f64 / s1.instructions as f64 - 2.0).abs() < 0.05,
            "2 SMs ≈ 2× instructions"
        );
    }

    #[test]
    fn ltrf_conf_not_slower_than_ltrf_at_high_latency() {
        let spec = suite::workload_by_name("gaussian").unwrap();
        let cfg = quick_cfg(HierarchyKind::Ltrf { plus: false }).with_latency_factor(6.3);
        let plain = run_workload(spec, &cfg, false);
        let conf = run_workload(spec, &cfg, true);
        // Renumbering's mechanism claim: fewer serialized bank accesses
        // during prefetch operations (§7.3).
        assert!(
            conf.prefetch_bank_conflicts <= plain.prefetch_bank_conflicts,
            "LTRF_conf conflicts {} vs LTRF {}",
            conf.prefetch_bank_conflicts,
            plain.prefetch_bank_conflicts
        );
        // And end-to-end it must stay in the same performance envelope
        // (per-workload IPC deltas of a few percent are expected noise;
        // the +3.8% mean is asserted at suite level in the coordinator).
        assert!(
            conf.ipc() >= plain.ipc() * 0.9,
            "LTRF_conf {} vs LTRF {}",
            conf.ipc(),
            plain.ipc()
        );
    }
}
