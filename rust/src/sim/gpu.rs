//! Multi-SM driver, in two interchangeable backends:
//!
//! * [`SimBackend::Reference`] — the original inline path: SMs step
//!   serially in lockstep and mutate the shared LLC/DRAM directly at
//!   issue time.
//! * [`SimBackend::Parallel`] — the two-phase core: each global cycle is
//!   (1) an embarrassingly-parallel per-SM step phase in which every SM
//!   computes locally and *records* its shared-level requests, then
//!   (2) a deterministic serial commit phase that drains those requests
//!   in canonical `(sm_id, seq)` order — `seq` being the per-SM issue
//!   order — applies them to the LLC/DRAM, and posts `MemArrive` replies.
//!
//! Determinism argument: the canonical commit order is exactly the order
//! in which the reference backend performs the same shared accesses (SMs
//! in ascending id, requests in issue order within an SM), every other
//! structure an SM touches during the step phase is SM-private, and an
//! instruction that records a request always counts as issued — so the
//! skip-ahead hint a stepping SM returns never depends on the
//! not-yet-known reply times. Both backends therefore produce
//! bit-identical [`Stats`] on every kernel, config, and seed; the
//! scenario backend-equivalence oracle and the CI snapshot gates enforce
//! this.
//!
//! The step phase additionally skips SMs whose previous hint lies beyond
//! the current cycle: the hint is a promise that no event fires and no
//! warp becomes issuable before it, so the only side effect a reference
//! step would have had is one `stall_no_ready_warp` increment — which the
//! driver applies directly (idle SMs are not polled every tick).
//!
//! Epoch commit batching: SMs interact *only* through the shared
//! LLC/DRAM, so an SM whose step recorded no shared-level op has nothing
//! to commit and an epoch in which no SM did needs no serial phase at
//! all. The two-phase drivers track dirty SMs per epoch (a list in the
//! single-threaded loop, per-SM flags in the threaded one, where the main
//! thread then locks only dirty SMs) and count clean epochs in
//! `Stats::commit_phases_skipped`. The counter is defined by the step
//! phase's observable work — "no SM performed or recorded a shared-level
//! op this epoch" — and booked at the same loop point by every backend,
//! including `Reference` (which has no commit phase but sees the same
//! per-epoch shared-op counts), so it stays bit-identical across
//! backends and thread counts.
//!
//! Ensemble replay across SMs: the interval steady-state replay engine
//! (see `sm.rs`) is armed unconditionally — any SM may fast-forward a
//! memory-quiescent steady-state window, not just a solo survivor. Two
//! driver-side obligations keep that invisible to the rest of the
//! machine. First, each epoch the driver hands every stepped SM a
//! *quiet horizon* — the minimum of the other live SMs' previous-epoch
//! hints — and the engine only commits a fast-forward whose window ends
//! at or before it, so no elided epoch is one in which another SM would
//! have acted (two SMs can never fast-forward in the same epoch: each
//! being due means its hint bounds the other's horizon at `now`).
//! Second, every elided epoch would have booked one driver-skip
//! `stall_no_ready_warp` on each other live SM — they were all idle
//! past the window, which is exactly what the horizon proves — so after
//! each step phase the driver drains [`SmSim::take_epoch_elided`] and
//! credits the count to the others via [`SmSim::add_skipped_polls`].
//! All three drivers skip idle SMs the same way (the reference driver
//! follows hints too — the provably-equivalent transformation noted in
//! the step loop) and compute horizons from the same previous-epoch
//! hints, so every replay decision is backend- and thread-invariant.
//! Each epoch a fast-forward elides would also have been a clean epoch
//! (pure in-SM work, no shared-level op), so [`finish`] folds the
//! per-SM elided-poll counts into `commit_phases_skipped`, keeping that
//! counter invariant across backends, thread counts, *and* the replay
//! on/off toggle.

use super::config::{SimBackend, SimConfig};
use super::memsys::SharedMem;
use super::sm::{MemPort, SmSim};
use super::stats::Stats;
use crate::compiler::{compile, CompileOptions, CompiledKernel};
use crate::util::sync::SpinBarrier;
use crate::workloads::gen;
use crate::workloads::WorkloadSpec;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run a compiled kernel under `cfg`. Resident warp count follows the MRF
/// capacity (TLP — §2.1); all SMs run the same kernel on staggered data.
pub fn run(ck: &CompiledKernel, cfg: &SimConfig) -> Stats {
    match cfg.backend {
        SimBackend::Reference => run_reference(ck, cfg),
        SimBackend::Parallel => run_parallel(ck, cfg),
    }
}

fn new_sms<'a>(ck: &'a CompiledKernel, cfg: &'a SimConfig) -> Vec<SmSim<'a>> {
    let resident = cfg.resident_warps(ck.kernel.num_regs);
    (0..cfg.num_sms).map(|s| SmSim::new(cfg, ck, resident, s)).collect()
}

/// Aggregate per-SM counters (including the L1 memory counters, which
/// `SmSim` folds into its own `Stats` at the access sites) via plain
/// merges, then attach the run-level cycle count, LLC counters, and the
/// cycle-cap truncation flag.
fn finish(
    sms: &[SmSim],
    shared: &SharedMem,
    now: u64,
    capped: bool,
    commit_skipped: u64,
) -> Stats {
    let mut total = Stats::default();
    for sm in sms {
        total.merge(&sm.stats);
    }
    total.cycles = now;
    total.llc_hits = shared.llc_hits;
    total.llc_misses = shared.llc_misses;
    // Epochs elided by replay fast-forwards would each have been clean;
    // folding them in keeps the counter replay-invariant (module doc).
    let elided: u64 = sms.iter().map(|sm| sm.elided_polls()).sum();
    total.commit_phases_skipped = commit_skipped + elided;
    if capped {
        total.hit_cycle_cap = 1;
    }
    total
}

/// Min and second-min (with the argmin) of the live SMs' previous-epoch
/// hints. SM `i`'s replay quiet horizon — the earliest cycle any *other*
/// live SM may act — is `min2` when `i` is the argmin and `min1`
/// otherwise (`u64::MAX` when no other SM is live). Ties are benign:
/// with two live SMs both due at `h`, each sees a horizon of `h`, which
/// correctly refuses any window extending past it.
fn quiet_horizons(hints: &[u64], dones: &[bool]) -> (u64, u64, Option<usize>) {
    let mut min1 = u64::MAX;
    let mut min2 = u64::MAX;
    let mut arg = None;
    for (i, (&h, &d)) in hints.iter().zip(dones).enumerate() {
        if d {
            continue;
        }
        if h < min1 {
            min2 = min1;
            min1 = h;
            arg = Some(i);
        } else if h < min2 {
            min2 = h;
        }
    }
    (min1, min2, arg)
}

/// After a step phase, credit the driver-skips that fast-forwarded
/// epochs elided: each elided epoch would have polled every other
/// still-live SM and found it idle (guaranteed by the quiet horizon), so
/// each would have booked one `stall_no_ready_warp` driver-skip there.
/// At most one SM fast-forwards per epoch (module doc), so the nested
/// sweep is O(n) in practice.
fn credit_elided_polls(sms: &mut [SmSim], dones: &[bool]) {
    for i in 0..sms.len() {
        let e = sms[i].take_epoch_elided();
        if e > 0 {
            for (j, sm) in sms.iter_mut().enumerate() {
                if j != i && !dones[j] {
                    sm.add_skipped_polls(e);
                }
            }
        }
    }
}

/// The reference backend: serial stepping with inline shared memory,
/// with global skip-ahead when no SM can make progress. Like the
/// two-phase drivers it follows per-SM hints — an SM whose previous
/// hint lies beyond `now` is not stepped, only credited the one
/// `stall_no_ready_warp` a poll would have booked (the provably
/// equivalent transformation described in the module doc). Hint-skipping
/// here is what makes each SM's poll cadence — and therefore every
/// replay recording — identical across all three drivers.
fn run_reference(ck: &CompiledKernel, cfg: &SimConfig) -> Stats {
    let mut shared = SharedMem::new(cfg.mem);
    let mut sms = new_sms(ck, cfg);
    let n = sms.len();
    let mut hints = vec![0u64; n];
    let mut dones = vec![false; n];

    let mut now: u64 = 0;
    let mut capped = false;
    let mut commit_skipped: u64 = 0;
    loop {
        // Replay quiet horizons come from the previous epoch's hints,
        // snapshotted before any SM steps so the values are independent
        // of step order (and of which backend is running).
        let (min1, min2, arg) = quiet_horizons(&hints, &dones);
        let mut any_shared = false;
        for i in 0..n {
            if dones[i] {
                continue;
            }
            if hints[i] > now {
                sms[i].note_skipped_poll();
                continue;
            }
            let quiet = if arg == Some(i) { min2 } else { min1 };
            hints[i] = sms[i].step(now, &mut MemPort::Inline(&mut shared), quiet);
            any_shared |= sms[i].shared_ops_this_step() > 0;
            dones[i] = sms[i].done();
        }
        credit_elided_polls(&mut sms, &dones);
        // No commit phase here, but the epoch classification must match
        // the two-phase drivers', so the counter is backend-invariant.
        // (Skipped and done SMs perform no shared ops, so the hint-skip
        // conversion leaves the classification unchanged.)
        if !any_shared {
            commit_skipped += 1;
        }
        if dones.iter().all(|&d| d) {
            break;
        }
        if now >= cfg.max_cycles {
            capped = true;
            break;
        }
        let next = hints
            .iter()
            .zip(&dones)
            .filter(|&(_, &d)| !d)
            .map(|(&h, _)| h)
            .min()
            .unwrap_or(u64::MAX);
        now = if next == u64::MAX { now + 1 } else { next.max(now + 1) };
    }
    finish(&sms, &shared, now, capped, commit_skipped)
}

/// Commit-order selector for [`run_two_phase`]. `PerturbedReversed`
/// exists only so tests can prove the backend-equivalence oracle trips
/// when the canonical order is violated; real backends always use
/// `Canonical`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitOrder {
    /// Ascending `sm_id`, per-SM issue order — the reference interleaving.
    Canonical,
    /// Descending `sm_id`, per-SM ops reversed (deliberately wrong).
    PerturbedReversed,
}

/// The parallel backend's driver. `sim_threads <= 1` (the default inside
/// engine jobs, which are already parallel at job granularity) runs the
/// same two-phase loop on the calling thread.
fn run_parallel(ck: &CompiledKernel, cfg: &SimConfig) -> Stats {
    let threads = cfg.sim_threads.clamp(1, cfg.num_sms.max(1));
    if threads <= 1 {
        run_two_phase(ck, cfg, CommitOrder::Canonical)
    } else {
        run_two_phase_threaded(ck, cfg, threads)
    }
}

/// Single-threaded two-phase loop. Public (with a selectable
/// [`CommitOrder`]) so the scenario tests can demonstrate that violating
/// the canonical commit order is caught by the equivalence oracle.
pub fn run_two_phase(ck: &CompiledKernel, cfg: &SimConfig, order: CommitOrder) -> Stats {
    let mut shared = SharedMem::new(cfg.mem);
    let mut sms = new_sms(ck, cfg);
    let n = sms.len();
    let mut hints = vec![0u64; n];
    let mut dones = vec![false; n];

    let mut now: u64 = 0;
    let mut capped = false;
    let mut commit_skipped: u64 = 0;
    let mut dirty: Vec<usize> = Vec::with_capacity(n);
    loop {
        // Replay quiet horizons from the previous epoch's hints (same
        // snapshot point as the other drivers — before any SM steps).
        let (min1, min2, arg) = quiet_horizons(&hints, &dones);
        // Phase 1: step every due SM (SM-local work only), tracking which
        // SMs recorded shared-level ops. Ascending index keeps the dirty
        // list in canonical `sm_id` order.
        dirty.clear();
        for i in 0..n {
            if dones[i] {
                continue;
            }
            if hints[i] > now {
                // Provably equivalent to stepping an idle SM: the hint
                // promises no event and no issuable warp before it, so a
                // reference step here would only bump the idle counter.
                sms[i].note_skipped_poll();
                continue;
            }
            let quiet = if arg == Some(i) { min2 } else { min1 };
            hints[i] = sms[i].step(now, &mut MemPort::Deferred, quiet);
            dones[i] = sms[i].done();
            if sms[i].has_pending_commit() {
                dirty.push(i);
            }
        }
        credit_elided_polls(&mut sms, &dones);
        // Phase 2: deterministic serial commit — dirty SMs only; a clean
        // epoch advances the clock without a commit phase.
        if dirty.is_empty() {
            commit_skipped += 1;
        }
        match order {
            CommitOrder::Canonical => {
                for &i in &dirty {
                    sms[i].commit_mem(&mut shared);
                }
            }
            CommitOrder::PerturbedReversed => {
                for &i in dirty.iter().rev() {
                    sms[i].commit_mem_perturbed(&mut shared);
                }
            }
        }
        if dones.iter().all(|&d| d) {
            break;
        }
        if now >= cfg.max_cycles {
            capped = true;
            break;
        }
        let next = hints
            .iter()
            .zip(&dones)
            .filter(|&(_, &d)| !d)
            .map(|(&h, _)| h)
            .min()
            .unwrap_or(u64::MAX);
        now = if next == u64::MAX { now + 1 } else { next.max(now + 1) };
    }
    finish(&sms, &shared, now, capped, commit_skipped)
}

/// Threaded two-phase loop: a persistent pool of `threads` workers claims
/// due SMs from a shared cursor each cycle (work-stealing-style dynamic
/// balance without per-cycle thread spawns), synchronized against the
/// main thread's serial commit phase by a spinning barrier. Produces the
/// same `Stats` bit-for-bit as [`run_two_phase`] at any thread count: the
/// step phase only touches SM-private state, and commit order is fixed by
/// `sm_id`, not by which worker stepped an SM.
///
/// Commit batching: workers flag SMs that recorded shared-level ops; the
/// main thread's commit phase locks only those (flag stores happen before
/// the S2 barrier, which is the happens-before edge into the commit
/// phase). A clean epoch — the common case once most warps are blocked on
/// long-latency memory — advances the clock without locking any SM.
fn run_two_phase_threaded(ck: &CompiledKernel, cfg: &SimConfig, threads: usize) -> Stats {
    let n = cfg.num_sms;
    let sms: Vec<Mutex<SmSim>> = new_sms(ck, cfg).into_iter().map(Mutex::new).collect();
    let hints: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let dones: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let dirty: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    // Per-epoch replay bookkeeping: epochs elided by an SM's fast-forward
    // this epoch (drained by the main thread's compensation sweep), and
    // the quiet-horizon triple the main thread publishes before each S1 —
    // seeded to match `quiet_horizons` over the initial hints (all zero,
    // all live), so epoch 0 sees the same horizons as the serial drivers.
    let elided: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let h_min1 = AtomicU64::new(0);
    let h_min2 = AtomicU64::new(if n > 1 { 0 } else { u64::MAX });
    let h_arg = AtomicUsize::new(0);
    // Workers + the committing main thread.
    let barrier = SpinBarrier::new(threads + 1);
    let now = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    let claim = AtomicUsize::new(0);

    let mut shared = SharedMem::new(cfg.mem);
    let mut final_now: u64 = 0;
    let mut capped = false;

    let commit_skipped = std::thread::scope(|scope| {
        for _ in 0..threads {
            let sms = &sms;
            let hints = &hints;
            let dones = &dones;
            let dirty = &dirty;
            let elided = &elided;
            let h_min1 = &h_min1;
            let h_min2 = &h_min2;
            let h_arg = &h_arg;
            let barrier = &barrier;
            let now = &now;
            let stop = &stop;
            let claim = &claim;
            scope.spawn(move || loop {
                barrier.wait(); // cycle start (S1)
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let t = now.load(Ordering::SeqCst);
                loop {
                    let i = claim.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    if dones[i].load(Ordering::SeqCst) {
                        continue;
                    }
                    let mut sm = sms[i].lock().unwrap();
                    if hints[i].load(Ordering::SeqCst) > t {
                        sm.note_skipped_poll();
                    } else {
                        // Quiet-horizon triple published by the main
                        // thread before this S1 (happens-before via the
                        // barrier), identical to the serial drivers'
                        // top-of-epoch `quiet_horizons` snapshot.
                        let quiet = if h_arg.load(Ordering::SeqCst) == i {
                            h_min2.load(Ordering::SeqCst)
                        } else {
                            h_min1.load(Ordering::SeqCst)
                        };
                        let h = sm.step(t, &mut MemPort::Deferred, quiet);
                        hints[i].store(h, Ordering::SeqCst);
                        if sm.done() {
                            dones[i].store(true, Ordering::SeqCst);
                        }
                        if sm.has_pending_commit() {
                            dirty[i].store(true, Ordering::SeqCst);
                        }
                        let e = sm.take_epoch_elided();
                        if e > 0 {
                            elided[i].store(e, Ordering::SeqCst);
                        }
                    }
                }
                barrier.wait(); // step phase complete (S2)
            });
        }

        // Main thread: serial commit phase (dirty SMs only) + clock
        // control. Hints, done flags, and dirty flags are atomics written
        // before the S2 barrier, so the clock sweep needs no SM locks; a
        // clean epoch takes none at all.
        let mut commit_skipped: u64 = 0;
        loop {
            barrier.wait(); // S1: release workers into the step phase
            barrier.wait(); // S2: all SMs stepped, workers idle at next S1
            let mut any_dirty = false;
            for i in 0..n {
                if dirty[i].swap(false, Ordering::SeqCst) {
                    any_dirty = true;
                    sms[i].lock().unwrap().commit_mem(&mut shared);
                }
            }
            if !any_dirty {
                commit_skipped += 1;
            }
            // Replay compensation sweep (same point as the serial
            // drivers': after the step phase, against post-step done
            // flags). Workers are parked at S1, so the locks are
            // uncontended; the common case is an all-zero sweep.
            for i in 0..n {
                let e = elided[i].swap(0, Ordering::SeqCst);
                if e > 0 {
                    for (j, sm) in sms.iter().enumerate() {
                        if j != i && !dones[j].load(Ordering::SeqCst) {
                            sm.lock().unwrap().add_skipped_polls(e);
                        }
                    }
                }
            }
            // Clock sweep; also recompute the quiet-horizon triple for
            // the next epoch (end-of-epoch here = the serial drivers'
            // top-of-next-epoch `quiet_horizons` call — `hints`/`dones`
            // are frozen in between).
            let mut all_done = true;
            let mut next = u64::MAX;
            let mut min1 = u64::MAX;
            let mut min2 = u64::MAX;
            let mut arg = usize::MAX;
            for i in 0..n {
                if !dones[i].load(Ordering::SeqCst) {
                    all_done = false;
                    let h = hints[i].load(Ordering::SeqCst);
                    next = next.min(h);
                    if h < min1 {
                        min2 = min1;
                        min1 = h;
                        arg = i;
                    } else if h < min2 {
                        min2 = h;
                    }
                }
            }
            let t = now.load(Ordering::SeqCst);
            if all_done || t >= cfg.max_cycles {
                capped = !all_done;
                final_now = t;
                stop.store(true, Ordering::SeqCst);
                barrier.wait(); // release workers so they observe `stop`
                break;
            }
            h_min1.store(min1, Ordering::SeqCst);
            h_min2.store(min2, Ordering::SeqCst);
            h_arg.store(arg, Ordering::SeqCst);
            let new_now = if next == u64::MAX { t + 1 } else { next.max(t + 1) };
            now.store(new_now, Ordering::SeqCst);
            claim.store(0, Ordering::SeqCst);
        }
        commit_skipped
    });

    let sms: Vec<SmSim> = sms.into_iter().map(|m| m.into_inner().unwrap()).collect();
    finish(&sms, &shared, final_now, capped, commit_skipped)
}

/// Compile options matching a simulator configuration.
pub fn compile_options(cfg: &SimConfig, renumber: bool) -> CompileOptions {
    CompileOptions {
        max_regs_per_interval: cfg.regs_per_interval,
        num_banks: cfg.mrf_banks,
        renumber,
        mode: cfg.hierarchy.subgraph_mode(),
        bank_map: cfg.bank_map,
    }
}

/// Build + compile + simulate one workload. `renumber` selects LTRF_conf
/// when the hierarchy is LTRF.
pub fn run_workload(spec: &WorkloadSpec, cfg: &SimConfig, renumber: bool) -> Stats {
    let kernel = gen::build(spec);
    let ck = compile(&kernel, compile_options(cfg, renumber));
    run(&ck, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::HierarchyKind;
    use crate::workloads::suite;

    fn quick_cfg(kind: HierarchyKind) -> SimConfig {
        SimConfig { max_cycles: 5_000_000, ..SimConfig::with_hierarchy(kind) }.normalize_capacity()
    }

    #[test]
    fn workload_runs_to_completion_bl_and_ltrf() {
        let spec = suite::workload_by_name("kmeans").unwrap();
        for kind in [HierarchyKind::Baseline, HierarchyKind::Ltrf { plus: false }] {
            let st = run_workload(spec, &quick_cfg(kind), false);
            assert!(st.warps_finished > 0, "{}", kind.name());
            assert!(st.cycles < 5_000_000, "{} hit the cycle cap", kind.name());
            assert_eq!(st.hit_cycle_cap, 0, "{} must not be truncated", kind.name());
        }
    }

    #[test]
    fn register_sensitive_workload_gains_tlp_from_bigger_rf() {
        let spec = suite::workload_by_name("cfd").unwrap();
        let small = quick_cfg(HierarchyKind::Ltrf { plus: false });
        let big = SimConfig { warp_regs_capacity: 16384, ..small };
        assert!(
            big.resident_warps(spec.regs_per_thread())
                > small.resident_warps(spec.regs_per_thread())
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let spec = suite::workload_by_name("hotspot").unwrap();
        let cfg = quick_cfg(HierarchyKind::Ltrf { plus: false });
        let a = run_workload(spec, &cfg, false);
        let b = run_workload(spec, &cfg, false);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.instructions, b.instructions);
    }

    #[test]
    fn multi_sm_scales_instruction_count() {
        let spec = suite::workload_by_name("lud").unwrap();
        let one = quick_cfg(HierarchyKind::Baseline);
        let two = SimConfig { num_sms: 2, ..one };
        let s1 = run_workload(spec, &one, false);
        let s2 = run_workload(spec, &two, false);
        assert!(
            (s2.instructions as f64 / s1.instructions as f64 - 2.0).abs() < 0.05,
            "2 SMs ≈ 2× instructions"
        );
    }

    #[test]
    fn ltrf_conf_not_slower_than_ltrf_at_high_latency() {
        let spec = suite::workload_by_name("gaussian").unwrap();
        let cfg = quick_cfg(HierarchyKind::Ltrf { plus: false }).with_latency_factor(6.3);
        let plain = run_workload(spec, &cfg, false);
        let conf = run_workload(spec, &cfg, true);
        // Renumbering's mechanism claim: fewer serialized bank accesses
        // during prefetch operations (§7.3).
        assert!(
            conf.prefetch_bank_conflicts <= plain.prefetch_bank_conflicts,
            "LTRF_conf conflicts {} vs LTRF {}",
            conf.prefetch_bank_conflicts,
            plain.prefetch_bank_conflicts
        );
        // And end-to-end it must stay in the same performance envelope
        // (per-workload IPC deltas of a few percent are expected noise;
        // the +3.8% mean is asserted at suite level in the coordinator).
        assert!(
            conf.ipc() >= plain.ipc() * 0.9,
            "LTRF_conf {} vs LTRF {}",
            conf.ipc(),
            plain.ipc()
        );
    }

    #[test]
    fn parallel_backend_bit_identical_single_sm() {
        let spec = suite::workload_by_name("kmeans").unwrap();
        for kind in HierarchyKind::ALL {
            let reference = run_workload(spec, &quick_cfg(kind), false);
            let par_cfg = SimConfig { backend: SimBackend::Parallel, ..quick_cfg(kind) };
            let parallel = run_workload(spec, &par_cfg, false);
            assert_eq!(reference, parallel, "{}", kind.name());
        }
    }

    #[test]
    fn parallel_backend_bit_identical_multi_sm_any_thread_count() {
        let spec = suite::workload_by_name("hotspot").unwrap();
        let base = SimConfig { num_sms: 3, ..quick_cfg(HierarchyKind::Ltrf { plus: true }) }
            .with_latency_factor(6.3);
        let reference = run_workload(spec, &base, false);
        for threads in [1usize, 2, 4] {
            let cfg = SimConfig { backend: SimBackend::Parallel, sim_threads: threads, ..base };
            assert_eq!(reference, run_workload(spec, &cfg, false), "threads={threads}");
        }
    }

    #[test]
    fn epoch_batching_skips_clean_commit_phases() {
        let spec = suite::workload_by_name("kmeans").unwrap();
        let base = SimConfig { num_sms: 2, ..quick_cfg(HierarchyKind::Ltrf { plus: false }) };
        let reference = run_workload(spec, &base, false);
        // Long-latency phases leave whole epochs without a shared-memory
        // op, and multi-thousand-cycle runs rotate the event wheel.
        assert!(reference.commit_phases_skipped > 0, "no clean epochs observed");
        assert!(reference.event_wheel_rollovers > 0, "no wheel rotations observed");
        // Both counters flow through `Stats` equality, but assert the
        // invariance explicitly so a failure names the counter.
        for threads in [1usize, 4] {
            let cfg = SimConfig { backend: SimBackend::Parallel, sim_threads: threads, ..base };
            let par = run_workload(spec, &cfg, false);
            assert_eq!(
                par.commit_phases_skipped, reference.commit_phases_skipped,
                "commit_phases_skipped diverged at threads={threads}"
            );
            assert_eq!(
                par.event_wheel_rollovers, reference.event_wheel_rollovers,
                "event_wheel_rollovers diverged at threads={threads}"
            );
        }
    }

    /// Pure-ALU steady-state loop — the deterministic replay trigger at
    /// driver level (suite workloads load inside their loops, which keeps
    /// them out of the recorded class by design — mirroring sm.rs's).
    const ALU_SRC: &str = r#"
.kernel a
  mov r0, #0
  mov r1, #7
L1:
  add r2, r0, r1
  add r3, r2, r1
  add r4, r3, r2
  add r0, r0, #1
  setp.lt p0, r0, #400
  @p0 bra L1
  st.global [r0], r4
  exit
"#;

    /// Zero the seven replay diagnostics so a replay-on run can be
    /// compared field-for-field against its dense twin.
    fn mask_replay_diagnostics(st: &mut Stats) {
        st.replay_fast_forwards = 0;
        st.replay_cycles_saved = 0;
        st.replay_ensemble_fast_forwards = 0;
        st.replay_ensemble_cycles_saved = 0;
        st.replay_cell_drops_mem = 0;
        st.replay_cell_drops_divergence = 0;
        st.replay_cell_drops_rotation = 0;
    }

    #[test]
    fn replay_counters_nonzero_and_invariant_at_driver_level() {
        // A memory-quiescent loop run by a single resident warp on a
        // single SM: the replay engine fast-forwards the steady state
        // from the first recorded window.
        let k = crate::ir::parser::parse(ALU_SRC).unwrap();
        let cfg = SimConfig {
            warps_per_sm: 1, // clamp to one resident warp
            ..SimConfig::with_hierarchy(HierarchyKind::Baseline)
        };
        let ck = compile(&k, compile_options(&cfg, false));
        let reference = run(&ck, &cfg);
        assert!(reference.replay_fast_forwards > 0, "solo ALU loop must fast-forward");
        assert!(reference.replay_cycles_saved > 0, "fast-forwards must claim cycles");
        let par = run(&ck, &SimConfig { backend: SimBackend::Parallel, ..cfg });
        assert_eq!(reference, par, "replay must stay backend-invariant");
        // Dense stepping agrees on every counter except the replay
        // diagnostics — including `commit_phases_skipped`, which `finish`
        // keeps replay-invariant by folding in the elided epochs.
        let mut dense = run(&ck, &SimConfig { replay: false, ..cfg });
        assert_eq!(dense.replay_fast_forwards, 0);
        assert_eq!(dense.replay_cycles_saved, 0);
        let mut masked = reference.clone();
        mask_replay_diagnostics(&mut masked);
        mask_replay_diagnostics(&mut dense);
        assert_eq!(masked, dense, "replay on/off diverged at driver level");
    }

    #[test]
    fn ensemble_replay_fires_multi_warp_at_driver_level() {
        // Two resident warps in the same ALU loop: the joint steady state
        // is what the ensemble engine records, so the ensemble counters
        // must move (and match the total — every cell here is multi-warp).
        let k = crate::ir::parser::parse(ALU_SRC).unwrap();
        let cfg = SimConfig {
            warps_per_sm: 2,
            ..SimConfig::with_hierarchy(HierarchyKind::Baseline)
        };
        let ck = compile(&k, compile_options(&cfg, false));
        let reference = run(&ck, &cfg);
        assert!(
            reference.replay_ensemble_fast_forwards > 0,
            "two-warp ALU loop must ensemble fast-forward"
        );
        assert!(reference.replay_ensemble_cycles_saved > 0);
        assert_eq!(
            reference.replay_fast_forwards, reference.replay_ensemble_fast_forwards,
            "with both warps live for the whole run, every cell is an ensemble cell"
        );
        for threads in [1usize, 4] {
            let cfg = SimConfig { backend: SimBackend::Parallel, sim_threads: threads, ..cfg };
            assert_eq!(reference, run(&ck, &cfg), "threads={threads}");
        }
        let mut dense = run(&ck, &SimConfig { replay: false, ..cfg });
        assert_eq!(dense.replay_ensemble_fast_forwards, 0);
        let mut masked = reference.clone();
        mask_replay_diagnostics(&mut masked);
        mask_replay_diagnostics(&mut dense);
        assert_eq!(masked, dense, "ensemble replay on/off diverged at driver level");
    }

    #[test]
    fn multi_sm_ensemble_replay_fires_with_live_peers() {
        // Two SMs, two warps each, same kernel: a strided-load warm-up
        // (every warp touches the same literal-addressed lines, so SM 0
        // misses to DRAM while SM 1 hits the lines SM 0 just filled in
        // the shared LLC — a deterministic desynchronization) followed by
        // a long pure-ALU loop. While one SM still sleeps on warm-up
        // misses, the other sits in its ALU steady state with a quiet
        // horizon wide enough to fast-forward — the multi-SM case the old
        // solo gate forbade. (Once the faster SM finishes outright, the
        // slower one fast-forwards under an infinite horizon, so the
        // liveness assertion does not hinge on the exact overlap.)
        let src = r#"
.kernel m
  mov r0, #65536
  mov r1, #0
L1:
  ld.global r2, [r0]
  add r0, r0, #128
  add r1, r1, #1
  setp.lt p0, r1, #16
  @p0 bra L1
  mov r1, #0
L2:
  add r3, r2, r1
  add r4, r3, r2
  add r5, r4, r3
  add r1, r1, #1
  setp.lt p0, r1, #600
  @p0 bra L2
  st.global [r0], r5
  exit
"#;
        let k = crate::ir::parser::parse(src).unwrap();
        let cfg = SimConfig {
            num_sms: 2,
            warps_per_sm: 2,
            ..SimConfig::with_hierarchy(HierarchyKind::Baseline)
        };
        let ck = compile(&k, compile_options(&cfg, false));
        let reference = run(&ck, &cfg);
        assert!(
            reference.replay_ensemble_fast_forwards > 0,
            "multi-SM ensemble steady state must fast-forward"
        );
        assert!(
            reference.replay_cell_drops_mem > 0,
            "the load loop must be blacklisted via the mem drop cause"
        );
        // The quiet horizon + elided-poll compensation must keep replay
        // decisions and every counter thread- and backend-invariant.
        for threads in [1usize, 4] {
            let cfg = SimConfig { backend: SimBackend::Parallel, sim_threads: threads, ..cfg };
            assert_eq!(reference, run(&ck, &cfg), "threads={threads}");
        }
        let mut dense = run(&ck, &SimConfig { replay: false, ..cfg });
        assert_eq!(dense.replay_fast_forwards, 0);
        assert_eq!(dense.replay_ensemble_fast_forwards, 0);
        let mut masked = reference.clone();
        mask_replay_diagnostics(&mut masked);
        mask_replay_diagnostics(&mut dense);
        assert_eq!(masked, dense, "multi-SM replay diverged from dense stepping");
    }

    #[test]
    fn multi_sm_replay_stays_silent_on_memory_windows() {
        // Regression for the LLC/DRAM gate the ensemble engine keeps: a
        // loop that loads every trip is never recordable, on any SM, so
        // dropping the solo-SM gate must not let memory windows replay.
        let src = r#"
.kernel s
  mov r0, #65536
  mov r1, #0
L1:
  ld.global r2, [r0]
  add r3, r2, r1
  add r0, r0, #128
  add r1, r1, #1
  setp.lt p0, r1, #32
  @p0 bra L1
  st.global [r0], r3
  exit
"#;
        let k = crate::ir::parser::parse(src).unwrap();
        let cfg = SimConfig {
            num_sms: 2,
            warps_per_sm: 4,
            ..SimConfig::with_hierarchy(HierarchyKind::Ltrf { plus: false })
        };
        assert!(cfg.replay, "replay is on by default");
        let ck = compile(&k, compile_options(&cfg, false));
        let st = run(&ck, &cfg);
        assert_eq!(st.replay_fast_forwards, 0, "memory windows must never fast-forward");
        assert_eq!(st.replay_ensemble_fast_forwards, 0);
        assert_eq!(st.replay_cycles_saved, 0);
        assert!(st.replay_cell_drops_mem > 0, "the mem drop cause must book the refusals");
        assert!(st.warps_finished > 0);
    }

    #[test]
    fn cycle_cap_truncation_is_recorded_not_silent() {
        let spec = suite::workload_by_name("kmeans").unwrap();
        for backend in [SimBackend::Reference, SimBackend::Parallel] {
            let cfg = SimConfig {
                max_cycles: 50,
                backend,
                ..SimConfig::with_hierarchy(HierarchyKind::Baseline)
            }
            .normalize_capacity();
            let st = run_workload(spec, &cfg, false);
            assert_eq!(st.hit_cycle_cap, 1, "{}", backend.name());
            assert!(st.warps_finished == 0 || st.cycles >= 50);
        }
    }
}
