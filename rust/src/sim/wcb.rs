//! Warp Control Block (§5.1, Fig. 12).
//!
//! Per-warp metadata for the prefetch machinery: the register-cache
//! address table (architectural register → RF$ bank), the working-set
//! bit-vector (valid bits), and the liveness bit-vector (LTRF+).

use super::alloc::AddressAllocationUnit;
use crate::util::RegSet;

const INVALID: u8 = 0xFF;

// `PartialEq`/`Eq` let the replay engine compare a warp's whole WCB
// between two loop-boundary snapshots (entry-state fingerprinting).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WarpControlBlock {
    /// RF$ bank number per architectural register (`INVALID` = not cached).
    addr_table: [u8; 256],
    /// Working-set bit-vector: registers currently resident in the RF$.
    pub valid: RegSet,
    /// Liveness bit-vector (LTRF+): registers holding a live value.
    pub live: RegSet,
    /// Registers written since they were fetched (need write-back).
    pub dirty: RegSet,
    /// Bank allocator for this warp's RF$ partition.
    pub aau: AddressAllocationUnit,
    /// Prefetch subgraph the warp is currently executing.
    pub current_interval: Option<usize>,
}

impl WarpControlBlock {
    pub fn new(partition_regs: usize) -> Self {
        WarpControlBlock {
            addr_table: [INVALID; 256],
            valid: RegSet::new(),
            live: RegSet::new(),
            dirty: RegSet::new(),
            aau: AddressAllocationUnit::new(partition_regs),
            current_interval: None,
        }
    }

    /// RF$ bank holding register `r`, if cached.
    pub fn bank_of(&self, r: u16) -> Option<u8> {
        let b = self.addr_table[r as usize];
        (b != INVALID).then_some(b)
    }

    /// Allocate RF$ space for `r` (idempotent). Returns the bank.
    pub fn allocate(&mut self, r: u16) -> u8 {
        if let Some(b) = self.bank_of(r) {
            return b;
        }
        let b = self
            .aau
            .alloc()
            .expect("RF$ partition exhausted: working set exceeded the compiler bound");
        self.addr_table[r as usize] = b;
        self.valid.insert(r);
        b
    }

    /// Release one register's slot.
    pub fn release(&mut self, r: u16) {
        if let Some(b) = self.bank_of(r) {
            self.aau.free(b);
            self.addr_table[r as usize] = INVALID;
            self.valid.remove(r);
            self.dirty.remove(r);
        }
    }

    /// Release the whole partition (warp deactivation — §5.2 "Warp
    /// Stall": clears all valid bits in the register cache address table).
    pub fn release_all(&mut self) {
        let valid = self.valid;
        for r in valid.iter() {
            self.release(r);
        }
        debug_assert!(self.valid.is_empty());
    }

    /// Number of cached registers.
    pub fn resident(&self) -> usize {
        self.valid.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_assigns_distinct_banks() {
        let mut wcb = WarpControlBlock::new(16);
        let b0 = wcb.allocate(3);
        let b1 = wcb.allocate(200);
        assert_ne!(b0, b1);
        assert_eq!(wcb.bank_of(3), Some(b0));
        assert_eq!(wcb.resident(), 2);
        // Idempotent.
        assert_eq!(wcb.allocate(3), b0);
        assert_eq!(wcb.resident(), 2);
    }

    #[test]
    fn release_all_clears_partition() {
        let mut wcb = WarpControlBlock::new(8);
        for r in 0..8u16 {
            wcb.allocate(r);
        }
        assert_eq!(wcb.aau.available(), 0);
        wcb.release_all();
        assert_eq!(wcb.aau.available(), 8);
        assert_eq!(wcb.resident(), 0);
        assert_eq!(wcb.bank_of(0), None);
    }

    #[test]
    #[should_panic(expected = "partition exhausted")]
    fn overflow_is_a_bug() {
        let mut wcb = WarpControlBlock::new(2);
        wcb.allocate(0);
        wcb.allocate(1);
        wcb.allocate(2);
    }

    #[test]
    fn dirty_tracking_independent_of_valid() {
        let mut wcb = WarpControlBlock::new(4);
        wcb.allocate(5);
        wcb.dirty.insert(5);
        wcb.release(5);
        assert!(!wcb.dirty.contains(5), "release clears dirty");
    }

    #[test]
    fn release_of_uncached_register_is_noop() {
        let mut wcb = WarpControlBlock::new(4);
        wcb.allocate(1);
        wcb.release(200); // never cached
        assert_eq!(wcb.resident(), 1);
        assert_eq!(wcb.aau.available(), 3);
        // Double release is also safe (no bank double-free).
        wcb.release(1);
        wcb.release(1);
        assert_eq!(wcb.aau.available(), 4);
    }

    #[test]
    fn release_preserves_liveness_bits() {
        // Liveness is a warp-level property (LTRF+ §3.2), not a residency
        // property: evicting a register must not mark it dead.
        let mut wcb = WarpControlBlock::new(4);
        wcb.allocate(7);
        wcb.live.insert(7);
        wcb.release(7);
        assert!(wcb.live.contains(7), "eviction must not kill the value");
        assert!(!wcb.valid.contains(7));
    }

    #[test]
    fn banks_recycle_fifo_after_release_all() {
        // The AAU hands banks back in free order: a full release followed
        // by re-allocation walks the banks in the order they were freed
        // (deterministic placement — renumbering depends on it).
        let mut wcb = WarpControlBlock::new(3);
        let b0 = wcb.allocate(10);
        let b1 = wcb.allocate(11);
        let b2 = wcb.allocate(12);
        wcb.release_all();
        // release_all frees in ascending register order (valid.iter()).
        assert_eq!(wcb.allocate(20), b0);
        assert_eq!(wcb.allocate(21), b1);
        assert_eq!(wcb.allocate(22), b2);
    }

    #[test]
    fn interval_eviction_pattern_coalesces() {
        // Allocate-evict-reallocate churn at partition capacity: the
        // address table must stay a bijection between resident registers
        // and banks throughout (the §5.1 RF$ invariant).
        let mut wcb = WarpControlBlock::new(2);
        for round in 0..10u16 {
            let a = round * 2;
            let b = round * 2 + 1;
            wcb.allocate(a);
            wcb.allocate(b);
            let (ba, bb) = (wcb.bank_of(a).unwrap(), wcb.bank_of(b).unwrap());
            assert_ne!(ba, bb, "round {round}: distinct banks");
            assert_eq!(wcb.resident(), 2);
            wcb.release(a);
            wcb.release(b);
            assert_eq!(wcb.aau.available(), 2, "round {round}: all banks back");
        }
    }
}
