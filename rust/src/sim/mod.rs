//! Cycle-level GPU SM simulator — the GPGPU-Sim stand-in.
//!
//! Models exactly the structures the paper's evaluation depends on:
//!
//! * a **two-level warp scheduler** ([`scheduler`]): a small active pool
//!   (8 warps) issues round-robin; a warp that misses in the L1 is
//!   descheduled and replaced from the pending pool (§3.2);
//! * **banked register files** ([`regfile`]): single-ported, non-pipelined
//!   banks whose conflicts serialize accesses — the central latency
//!   mechanism of the paper;
//! * the **register-file hierarchies** under study ([`hierarchy`]), as
//!   pluggable [`hierarchy::HierarchyModel`] policies over shared timing
//!   resources: BL (no cache), RFC (hardware register cache, Gebhart
//!   ISCA'11), SHRF (compiler-managed strands, Gebhart MICRO'11), LTRF /
//!   LTRF+ / LTRF_conf (software register-interval prefetching, this
//!   paper), and CARF (compiler-assisted RF cache, Shoushtary et al.);
//! * the **Warp Control Block** ([`wcb`]) and **Address Allocation Unit**
//!   ([`alloc`]) of §5.1–5.2;
//! * a latency/bandwidth **memory system** ([`memsys`]): L1D per SM,
//!   shared LLC, bandwidth-limited DRAM channels.
//!
//! Timing discipline: issue is cycle-stepped; register-bank and
//! interconnect occupancy are tracked as busy-until resources, which
//! preserves queueing and conflict serialization without a per-port
//! event loop (see DESIGN.md §Substitutions).
//!
//! Multi-SM stepping comes in two bit-identical backends (see [`gpu`] and
//! [`config::SimBackend`]): the serial `Reference` path and the two-phase
//! `Parallel` core, which steps SMs data-parallel against per-SM request
//! arenas and commits shared-memory effects in canonical `(sm_id, seq)`
//! order.

pub mod alloc;
pub mod config;
pub mod gpu;
pub mod hierarchy;
pub mod memsys;
pub mod regfile;
pub mod rfc;
pub mod scheduler;
pub mod sm;
pub mod stats;
pub mod warp;
pub mod wcb;
pub mod wheel;

pub use config::{HierarchyKind, MemConfig, SimBackend, SimConfig};
pub use gpu::{run, run_workload};
pub use hierarchy::{model_for, HierarchyModel, HierarchyResources, RegHierarchy, Traffic};
pub use stats::Stats;
