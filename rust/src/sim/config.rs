//! Simulated system configuration (the paper's Table 3).

use crate::compiler::{BankMap, SubgraphMode};
use crate::timing::RfDesign;

/// Which register-file hierarchy the SM runs (§6 comparison points).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HierarchyKind {
    /// Conventional non-cached register file (BL). For fairness the RF$
    /// capacity is added to the MRF (§6).
    Baseline,
    /// Hardware register-file cache, Gebhart ISCA'11 (RFC): per-active-warp
    /// FIFO cache, allocate on access, write-back on eviction.
    Rfc,
    /// Software-managed hierarchical RF, Gebhart MICRO'11 (SHRF):
    /// strand-scoped compiler-managed partitions, on-demand fill.
    Shrf,
    /// This paper: software register-interval prefetching. `plus` enables
    /// LTRF+ liveness filtering (§3.2). (LTRF_conf is LTRF compiled with
    /// `CompileOptions::renumber = true`.)
    Ltrf { plus: bool },
    /// Compiler-assisted register-file cache, Shoushtary et al.
    /// (arXiv:2310.17501): no prefetch, on-demand fill, allocate on
    /// write, liveness-directed eviction via the compiler's dead-operand
    /// bits (the §3.2 analysis LTRF+ consumes).
    Carf,
}

impl HierarchyKind {
    /// Every simulated policy, in registry/presentation order. The
    /// canonical comparison matrix (names, compile flags, latency points)
    /// lives in `coordinator::designs`; this list only spans the enum.
    pub const ALL: [HierarchyKind; 6] = [
        HierarchyKind::Baseline,
        HierarchyKind::Rfc,
        HierarchyKind::Shrf,
        HierarchyKind::Ltrf { plus: false },
        HierarchyKind::Ltrf { plus: true },
        HierarchyKind::Carf,
    ];

    pub fn name(self) -> &'static str {
        match self {
            HierarchyKind::Baseline => "BL",
            HierarchyKind::Rfc => "RFC",
            HierarchyKind::Shrf => "SHRF",
            HierarchyKind::Ltrf { plus: false } => "LTRF",
            HierarchyKind::Ltrf { plus: true } => "LTRF+",
            HierarchyKind::Carf => "CARF",
        }
    }

    /// Does this hierarchy consume compiled prefetch subgraphs?
    pub fn uses_subgraphs(self) -> bool {
        matches!(self, HierarchyKind::Shrf | HierarchyKind::Ltrf { .. })
    }

    /// The compile mode this hierarchy expects.
    pub fn subgraph_mode(self) -> SubgraphMode {
        match self {
            HierarchyKind::Shrf => SubgraphMode::Strands,
            _ => SubgraphMode::RegisterIntervals,
        }
    }

    /// Does the policy keep enough operand traffic off the MRF to
    /// tolerate multi-cycle MRF latency (Fig. 15's high-tolerance band)?
    /// BL/RFC collapse by 2–3×; every software-managed cache scans to the
    /// top of the figure. Drives the tolerable-latency planning horizon.
    pub fn latency_tolerant(self) -> bool {
        !matches!(self, HierarchyKind::Baseline | HierarchyKind::Rfc)
    }
}

/// Which multi-SM stepping strategy `gpu::run` uses. Both backends are
/// required to produce bit-identical [`super::stats::Stats`] on every
/// kernel/config/seed — enforced by the scenario backend-equivalence
/// oracle and the CI snapshot gates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum SimBackend {
    /// The original inline path: SMs step serially in lockstep and mutate
    /// the shared LLC/DRAM directly at issue time.
    #[default]
    Reference,
    /// Two-phase core: an embarrassingly-parallel per-SM step phase that
    /// *records* LLC requests, then a deterministic serial commit phase
    /// that drains them in canonical `(sm_id, seq)` order.
    Parallel,
}

impl SimBackend {
    pub fn name(self) -> &'static str {
        match self {
            SimBackend::Reference => "reference",
            SimBackend::Parallel => "parallel",
        }
    }

    pub fn by_name(name: &str) -> Option<SimBackend> {
        match name {
            "reference" => Some(SimBackend::Reference),
            "parallel" => Some(SimBackend::Parallel),
            _ => None,
        }
    }
}

/// Memory system parameters (Table 3 + GDDR5 timing abstracted to
/// latency/bandwidth).
#[derive(Clone, Copy, Debug)]
pub struct MemConfig {
    /// L1D: 16KB, 4-way, 128B lines per SM.
    pub l1_lines: usize,
    pub l1_assoc: usize,
    pub l1_hit_cycles: u32,
    /// Shared LLC: 2MB, 8-way, 128B lines.
    pub llc_lines: usize,
    pub llc_assoc: usize,
    pub llc_hit_cycles: u32,
    /// DRAM: 8 channels, fixed access latency + per-channel service rate.
    pub dram_channels: usize,
    pub dram_latency: u32,
    /// Cycles a channel is occupied per 128B line (bandwidth limit).
    pub dram_service_cycles: u32,
    /// MSHRs per SM (max outstanding L1 misses).
    pub mshrs: usize,
    /// Shared-memory access latency.
    pub shared_cycles: u32,
}

impl Default for MemConfig {
    fn default() -> Self {
        MemConfig {
            l1_lines: 128, // 16KB / 128B
            l1_assoc: 4,
            l1_hit_cycles: 24,
            llc_lines: 16384, // 2MB / 128B
            llc_assoc: 8,
            llc_hit_cycles: 120,
            dram_channels: 8,
            dram_latency: 220,
            dram_service_cycles: 2,
            mshrs: 32,
            shared_cycles: 24,
        }
    }
}

/// Full simulated-system configuration. Defaults reproduce Table 3 with
/// one simulated SM (the paper's 24 SMs are homogeneous; IPC/SM is the
/// reported metric — see DESIGN.md).
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub num_sms: usize,
    /// Hardware warp contexts per SM (Table 3: 64).
    pub warps_per_sm: usize,
    /// Two-level scheduler active pool (Table 3: 8).
    pub active_warps: usize,
    /// MRF capacity in 1024-bit warp-registers (Table 3: 2048 = 256KB).
    pub warp_regs_capacity: usize,
    /// MRF banks (Table 3: 16).
    pub mrf_banks: usize,
    /// MRF bank access latency in cycles (data-ready time).
    pub mrf_access_cycles: u32,
    /// MRF bank busy time per access. 1 (pipelined) for baseline HP SRAM;
    /// = access latency for the slow non-pipelined technologies.
    pub mrf_occupancy_cycles: u32,
    /// RF$ bank access cycles (the fast level).
    pub cache_access_cycles: u32,
    /// RF$ partition size in registers (= max regs per register-interval;
    /// Table 3: 16).
    pub regs_per_interval: usize,
    /// Operand collectors per SM (bounds in-flight collecting insts).
    pub operand_collectors: usize,
    /// Issue slots per cycle per SM.
    pub issue_width: usize,
    /// ALU pipeline latency.
    pub alu_cycles: u32,
    /// SFU latency.
    pub sfu_cycles: u32,
    /// MRF→RF$ crossbar: registers transferred per cycle (narrowed 4×
    /// from the baseline 4-reg-wide crossbar — §5.2).
    pub xbar_regs_per_cycle: u32,
    /// MRF→RF$ crossbar traversal latency in cycles (§5.2: 4).
    pub xbar_latency: u32,
    /// RFC capacity per active warp, in registers (16KB total / 8 warps /
    /// 128B = 16).
    pub rfc_regs_per_warp: usize,
    pub mem: MemConfig,
    pub hierarchy: HierarchyKind,
    /// Register→bank mapping for the MRF.
    pub bank_map: BankMap,
    /// Start the reactivation working-set refetch when the blocking miss
    /// returns, before the warp re-enters the active pool (§3.2). Ablation
    /// knob; disabling it serializes refetch with pool occupancy.
    pub early_refetch: bool,
    /// Interval steady-state replay: fingerprint the joint state of all
    /// live warps on an SM at back-edge-aligned epochs and, after two
    /// identical memory-quiescent periods, fast-forward whole SM-local
    /// steady states from the recorded ensemble cell instead of dense
    /// stepping (see `sim::sm`). Legal on any SM whose window issues no
    /// LLC/DRAM-visible memory traffic and fits under the driver's quiet
    /// horizon (see `sim::gpu`). Stats are bit-identical either way
    /// except the seven `replay_*` diagnostic counters — enforced by the
    /// replay-equivalence oracle.
    pub replay: bool,
    /// Safety valve for runaway simulations.
    pub max_cycles: u64,
    /// Multi-SM stepping strategy (see [`SimBackend`]).
    pub backend: SimBackend,
    /// Worker threads for the `Parallel` backend's step phase (capped at
    /// `num_sms`). Default 1: engine jobs are already parallel at job
    /// granularity, so nesting defaults off to avoid oversubscription.
    pub sim_threads: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            num_sms: 1,
            warps_per_sm: 64,
            active_warps: 8,
            warp_regs_capacity: 2048,
            mrf_banks: 16,
            mrf_access_cycles: 2,
            mrf_occupancy_cycles: 1,
            cache_access_cycles: 1,
            regs_per_interval: 16,
            operand_collectors: 16,
            issue_width: 2,
            alu_cycles: 4,
            sfu_cycles: 16,
            xbar_regs_per_cycle: 4,
            xbar_latency: 4,
            rfc_regs_per_warp: 6,
            mem: MemConfig::default(),
            hierarchy: HierarchyKind::Baseline,
            bank_map: BankMap::Interleave,
            early_refetch: true,
            replay: true,
            max_cycles: 30_000_000,
            backend: SimBackend::Reference,
            sim_threads: 1,
        }
    }
}

impl SimConfig {
    /// Table-3 baseline with a given hierarchy.
    pub fn with_hierarchy(h: HierarchyKind) -> Self {
        SimConfig { hierarchy: h, ..Default::default() }
    }

    /// Apply a Table-2 register-file design: capacity and access latency
    /// scale; `latency_override` replaces the design's latency factor
    /// (used for the Ideal point and for tolerable-latency sweeps).
    pub fn apply_design(mut self, d: &RfDesign, latency_override: Option<f64>) -> Self {
        let factor = latency_override.unwrap_or_else(|| d.latency());
        self.warp_regs_capacity = d.warp_registers();
        self = self.with_latency_factor(factor);
        self.mrf_banks = d.num_banks().min(128);
        self
    }

    /// Scale only the MRF latency by `factor` (×1 = Table-3 baseline).
    /// Factors ≤ 1.25 model pipelined SRAM banks (occupancy 1); slower
    /// cells use the non-pipelined CACTI bank model (occupancy = latency).
    pub fn with_latency_factor(mut self, factor: f64) -> Self {
        self.mrf_access_cycles = crate::timing::bank::cycles(factor, 2);
        self.mrf_occupancy_cycles = if factor <= 1.25 { 1 } else { self.mrf_access_cycles };
        self
    }

    /// BL fairness adjustment (§6): fold the 16KB RF$ capacity into the
    /// MRF when no cache level exists.
    pub fn normalize_capacity(mut self) -> Self {
        if matches!(self.hierarchy, HierarchyKind::Baseline) {
            self.warp_regs_capacity += self.regs_per_interval * self.active_warps;
        }
        self
    }

    /// Resident warps for a workload needing `regs_per_thread` registers.
    pub fn resident_warps(&self, regs_per_thread: u16) -> usize {
        (self.warp_regs_capacity / regs_per_thread.max(1) as usize).clamp(1, self.warps_per_sm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::DESIGN_7_DWM;

    #[test]
    fn defaults_match_table3() {
        let c = SimConfig::default();
        assert_eq!(c.warps_per_sm, 64);
        assert_eq!(c.active_warps, 8);
        assert_eq!(c.warp_regs_capacity, 2048); // 256KB
        assert_eq!(c.mrf_banks, 16);
        assert_eq!(c.regs_per_interval, 16);
        // RF$ = 16 regs × 8 warps × 128B = 16KB (Table 3).
        assert_eq!(c.regs_per_interval * c.active_warps * 128, 16 * 1024);
    }

    #[test]
    fn design_application_scales_latency_and_capacity() {
        let c = SimConfig::with_hierarchy(HierarchyKind::Ltrf { plus: false })
            .apply_design(&DESIGN_7_DWM, None);
        assert_eq!(c.warp_regs_capacity, 16384); // 2MB
        assert_eq!(c.mrf_access_cycles, 13); // 6.3 × 2 rounded
        assert_eq!(c.mrf_occupancy_cycles, 13); // non-pipelined DWM
        assert_eq!(c.mrf_banks, 128);
    }

    #[test]
    fn baseline_gets_rfc_capacity_back() {
        let c = SimConfig::with_hierarchy(HierarchyKind::Baseline).normalize_capacity();
        assert_eq!(c.warp_regs_capacity, 2048 + 128);
        let l = SimConfig::with_hierarchy(HierarchyKind::Ltrf { plus: false }).normalize_capacity();
        assert_eq!(l.warp_regs_capacity, 2048);
    }

    #[test]
    fn backend_names_roundtrip_and_default_is_reference() {
        assert_eq!(SimConfig::default().backend, SimBackend::Reference);
        assert_eq!(SimConfig::default().sim_threads, 1);
        for b in [SimBackend::Reference, SimBackend::Parallel] {
            assert_eq!(SimBackend::by_name(b.name()), Some(b));
        }
        assert_eq!(SimBackend::by_name("nonsense"), None);
    }

    #[test]
    fn hierarchy_names_and_modes() {
        assert_eq!(HierarchyKind::Baseline.name(), "BL");
        assert_eq!(HierarchyKind::Ltrf { plus: true }.name(), "LTRF+");
        assert_eq!(HierarchyKind::Carf.name(), "CARF");
        assert_eq!(
            HierarchyKind::Shrf.subgraph_mode(),
            crate::compiler::SubgraphMode::Strands
        );
        assert_eq!(
            HierarchyKind::Carf.subgraph_mode(),
            crate::compiler::SubgraphMode::RegisterIntervals
        );
        assert!(!HierarchyKind::Rfc.uses_subgraphs());
        assert!(HierarchyKind::Ltrf { plus: false }.uses_subgraphs());
        assert!(!HierarchyKind::Carf.uses_subgraphs(), "CARF has no prefetch");
        // ALL spans the enum exactly once.
        let names: std::collections::HashSet<_> =
            HierarchyKind::ALL.iter().map(|k| k.name()).collect();
        assert_eq!(names.len(), HierarchyKind::ALL.len());
        // Latency tolerance splits BL/RFC from the software-managed caches.
        assert!(!HierarchyKind::Baseline.latency_tolerant());
        assert!(!HierarchyKind::Rfc.latency_tolerant());
        for k in [HierarchyKind::Shrf, HierarchyKind::Ltrf { plus: true }, HierarchyKind::Carf] {
            assert!(k.latency_tolerant(), "{}", k.name());
        }
    }
}
