//! Thin wrapper around the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids, which xla_extension 0.5.1 rejects
//! (`proto.id() <= INT_MAX`); the text parser reassigns ids and
//! round-trips cleanly (see python/compile/aot.py).

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client plus executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(exe)
    }

    /// Execute with literal inputs; returns the first device's output.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let result = exe.execute::<xla::Literal>(inputs).context("PJRT execute")?;
        let lit = result[0][0].to_literal_sync().context("fetching result literal")?;
        Ok(lit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The artifact path used across runtime tests (built by
    /// `make artifacts`; tests that need it are skipped when absent so
    /// `cargo test` works before the first build).
    pub fn artifact_path() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/prefetch_eval.hlo.txt")
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn load_and_execute_artifact_smoke() {
        let path = artifact_path();
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first ({})", path.display());
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let exe = rt.load_hlo_text(&path).expect("compile artifact");
        // Zero batch: all outputs zero.
        let ws = xla::Literal::vec1(&vec![0u32; 1024 * 8]).reshape(&[1024, 8]).unwrap();
        let onehot = xla::Literal::vec1(&vec![0f32; 256 * 16]).reshape(&[256, 16]).unwrap();
        let s = xla::Literal::from(1.0f32);
        let out = rt
            .execute(&exe, &[ws, onehot, s.clone(), s.clone(), s])
            .expect("execute");
        let (counts, conflicts, latency, total) = out.to_tuple4().expect("4-tuple output");
        assert_eq!(counts.to_vec::<f32>().unwrap().len(), 1024 * 16);
        assert!(conflicts.to_vec::<f32>().unwrap().iter().all(|&x| x == 0.0));
        assert!(latency.to_vec::<f32>().unwrap().iter().all(|&x| x == 0.0));
        assert!(total.to_vec::<f32>().unwrap().iter().all(|&x| x == 0.0));
    }
}
