//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The build path (`make artifacts`) runs Python once to lower the L2
//! model (which embeds the L1 Pallas kernel) to HLO text; this module
//! loads that text with `HloModuleProto::from_text_file`, compiles it on
//! the PJRT CPU client, and executes it from the L3 sweep path. Python is
//! never on the request path.

pub mod pjrt;
pub mod prefetch_eval;

pub use pjrt::PjrtRuntime;
pub use prefetch_eval::{EvalRow, PrefetchEvaluator};
