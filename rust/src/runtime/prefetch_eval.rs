//! Batched prefetch evaluation: PJRT-backed with a pure-rust reference.
//!
//! `PrefetchEvaluator` answers, for a batch of prefetch bit-vectors under
//! a register→bank assignment: per-bank occupancy, the §4 conflict count,
//! and the serialized prefetch latency. The PJRT backend runs the AOT
//! artifact (L1 Pallas kernel inside the L2 model); `Reference` is the
//! bit-identical rust implementation used for cross-checking and as a
//! fallback when `artifacts/` has not been built.

use super::pjrt::PjrtRuntime;
use crate::compiler::BankMap;
use crate::util::bitset::MAX_REGS;
use crate::util::RegSet;
use anyhow::{Context, Result};
use std::path::Path;

/// Artifact batch geometry (must match python/compile/kernels).
pub const N_BATCH: usize = 1024;
const NUM_BANKS: usize = 16;

/// Per-interval evaluation result.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalRow {
    /// Registers per bank.
    pub counts: [u32; NUM_BANKS],
    /// Extra serialized bank accesses: `max(counts) - 1` (0 if empty).
    pub conflicts: u32,
    /// Serialized prefetch cycles (0 if empty).
    pub latency: u32,
    /// Working-set size.
    pub total: u32,
}

/// Latency-model parameters (mirrors python/compile/model.py).
#[derive(Clone, Copy, Debug)]
pub struct LatencyParams {
    pub mrf_cycles: f32,
    pub xbar_rate: f32,
    pub xbar_latency: f32,
}

impl Default for LatencyParams {
    fn default() -> Self {
        LatencyParams { mrf_cycles: 13.0, xbar_rate: 2.0, xbar_latency: 4.0 }
    }
}

enum Backend {
    Pjrt { rt: PjrtRuntime, exe: xla::PjRtLoadedExecutable },
    Reference,
}

/// Batched evaluator.
pub struct PrefetchEvaluator {
    backend: Backend,
}

impl PrefetchEvaluator {
    /// Load the PJRT artifact from `artifacts/prefetch_eval.hlo.txt`.
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let path = artifact_dir.join("prefetch_eval.hlo.txt");
        let rt = PjrtRuntime::cpu()?;
        let exe = rt
            .load_hlo_text(&path)
            .with_context(|| format!("loading {}", path.display()))?;
        Ok(PrefetchEvaluator { backend: Backend::Pjrt { rt, exe } })
    }

    /// PJRT if the artifact exists, else the rust reference.
    pub fn load_or_reference(artifact_dir: &Path) -> Self {
        Self::load(artifact_dir).unwrap_or_else(|_| Self::reference())
    }

    /// Pure-rust reference backend.
    pub fn reference() -> Self {
        PrefetchEvaluator { backend: Backend::Reference }
    }

    pub fn is_pjrt(&self) -> bool {
        matches!(self.backend, Backend::Pjrt { .. })
    }

    /// Evaluate a batch of working sets under a bank assignment
    /// (`assign[r]` = bank of register `r`).
    pub fn evaluate(
        &self,
        sets: &[RegSet],
        assign: &[usize; MAX_REGS],
        params: LatencyParams,
    ) -> Result<Vec<EvalRow>> {
        match &self.backend {
            Backend::Reference => Ok(evaluate_reference(sets, assign, params)),
            Backend::Pjrt { rt, exe } => {
                let mut out = Vec::with_capacity(sets.len());
                for chunk in sets.chunks(N_BATCH) {
                    out.extend(run_pjrt_batch(rt, exe, chunk, assign, params)?);
                }
                Ok(out)
            }
        }
    }

    /// Convenience: evaluate under a structural bank map.
    pub fn evaluate_mapped(
        &self,
        sets: &[RegSet],
        map: BankMap,
        num_banks: usize,
        params: LatencyParams,
    ) -> Result<Vec<EvalRow>> {
        assert_eq!(num_banks, NUM_BANKS, "the AOT artifact is built for 16 banks");
        let mut assign = [0usize; MAX_REGS];
        for (r, a) in assign.iter_mut().enumerate() {
            *a = map.bank_of(r as u16, num_banks);
        }
        self.evaluate(sets, &assign, params)
    }
}

/// The rust reference implementation (bit-identical to the artifact:
/// all quantities are small integers, exact in f32).
pub fn evaluate_reference(
    sets: &[RegSet],
    assign: &[usize; MAX_REGS],
    params: LatencyParams,
) -> Vec<EvalRow> {
    sets.iter()
        .map(|ws| {
            let mut counts = [0u32; NUM_BANKS];
            for r in ws.iter() {
                counts[assign[r as usize] % NUM_BANKS] += 1;
            }
            let max_occ = counts.iter().copied().max().unwrap_or(0);
            let total: u32 = counts.iter().sum();
            let conflicts = max_occ.saturating_sub(1);
            let latency = if total > 0 {
                let busy = max_occ as f32 * params.mrf_cycles;
                let transfer = (total as f32 / params.xbar_rate).ceil();
                (busy + transfer + params.xbar_latency) as u32
            } else {
                0
            };
            EvalRow { counts, conflicts, latency, total }
        })
        .collect()
}

fn run_pjrt_batch(
    rt: &PjrtRuntime,
    exe: &xla::PjRtLoadedExecutable,
    sets: &[RegSet],
    assign: &[usize; MAX_REGS],
    params: LatencyParams,
) -> Result<Vec<EvalRow>> {
    // Pack working sets into u32 lanes, zero-padded to N_BATCH.
    let mut ws = vec![0u32; N_BATCH * 8];
    for (i, s) in sets.iter().enumerate() {
        let lanes = s.to_u32_lanes();
        ws[i * 8..i * 8 + 8].copy_from_slice(&lanes);
    }
    // One-hot bank matrix.
    let mut onehot = vec![0f32; MAX_REGS * NUM_BANKS];
    for (r, &b) in assign.iter().enumerate() {
        onehot[r * NUM_BANKS + (b % NUM_BANKS)] = 1.0;
    }

    let ws_lit = xla::Literal::vec1(&ws).reshape(&[N_BATCH as i64, 8])?;
    let oh_lit = xla::Literal::vec1(&onehot).reshape(&[MAX_REGS as i64, NUM_BANKS as i64])?;
    let out = rt.execute(
        exe,
        &[
            ws_lit,
            oh_lit,
            xla::Literal::from(params.mrf_cycles),
            xla::Literal::from(params.xbar_rate),
            xla::Literal::from(params.xbar_latency),
        ],
    )?;
    let (counts, conflicts, latency, total) = out.to_tuple4().context("artifact 4-tuple")?;
    let counts = counts.to_vec::<f32>()?;
    let conflicts = conflicts.to_vec::<f32>()?;
    let latency = latency.to_vec::<f32>()?;
    let total = total.to_vec::<f32>()?;

    Ok((0..sets.len())
        .map(|i| {
            let mut c = [0u32; NUM_BANKS];
            for (b, slot) in c.iter_mut().enumerate() {
                *slot = counts[i * NUM_BANKS + b] as u32;
            }
            EvalRow {
                counts: c,
                conflicts: conflicts[i] as u32,
                latency: latency[i] as u32,
                total: total[i] as u32,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn interleave_assign() -> [usize; MAX_REGS] {
        let mut a = [0usize; MAX_REGS];
        for (r, slot) in a.iter_mut().enumerate() {
            *slot = r % NUM_BANKS;
        }
        a
    }

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn reference_known_values() {
        let sets = vec![
            RegSet::from_iter([0u16, 16, 32]), // 3 in bank 0
            RegSet::from_iter([0u16, 1, 2, 3]),
            RegSet::new(),
        ];
        let rows = evaluate_reference(&sets, &interleave_assign(), LatencyParams::default());
        assert_eq!(rows[0].conflicts, 2);
        assert_eq!(rows[0].counts[0], 3);
        // 3×13 + ceil(3/2) + 4 = 45.
        assert_eq!(rows[0].latency, 45);
        assert_eq!(rows[1].conflicts, 0);
        assert_eq!(rows[2].latency, 0);
        assert_eq!(rows[2].total, 0);
    }

    #[test]
    fn pjrt_matches_reference_exactly() {
        let ev = match PrefetchEvaluator::load(&artifact_dir()) {
            Ok(ev) => ev,
            Err(e) => {
                eprintln!("skipping (run `make artifacts`): {e:#}");
                return;
            }
        };
        let mut rng = crate::util::Xoshiro256::seeded(0xE7A1);
        let sets: Vec<RegSet> = (0..300)
            .map(|_| {
                let n = rng.range(0, 24);
                RegSet::from_iter((0..n).map(|_| rng.below(MAX_REGS as u64) as u16))
            })
            .collect();
        let mut assign = [0usize; MAX_REGS];
        for a in assign.iter_mut() {
            *a = rng.below(NUM_BANKS as u64) as usize;
        }
        let params = LatencyParams { mrf_cycles: 13.0, xbar_rate: 2.0, xbar_latency: 4.0 };
        let got = ev.evaluate(&sets, &assign, params).unwrap();
        let want = evaluate_reference(&sets, &assign, params);
        assert_eq!(got, want, "PJRT artifact must be bit-identical to the rust reference");
    }

    #[test]
    fn pjrt_handles_multi_batch() {
        let ev = match PrefetchEvaluator::load(&artifact_dir()) {
            Ok(ev) => ev,
            Err(_) => return,
        };
        let sets: Vec<RegSet> =
            (0..N_BATCH + 7).map(|i| RegSet::singleton((i % 256) as u16)).collect();
        let rows = ev
            .evaluate(&sets, &interleave_assign(), LatencyParams::default())
            .unwrap();
        assert_eq!(rows.len(), N_BATCH + 7);
        assert!(rows.iter().all(|r| r.total == 1));
    }

    #[test]
    fn evaluate_mapped_matches_compiler_conflicts() {
        use crate::compiler::renumber::bank_conflicts;
        let ev = PrefetchEvaluator::reference();
        let mut rng = crate::util::Xoshiro256::seeded(77);
        let sets: Vec<RegSet> = (0..64)
            .map(|_| {
                let n = rng.range(1, 16);
                RegSet::from_iter((0..n).map(|_| rng.below(MAX_REGS as u64) as u16))
            })
            .collect();
        let rows = ev
            .evaluate_mapped(&sets, BankMap::Interleave, 16, LatencyParams::default())
            .unwrap();
        for (ws, row) in sets.iter().zip(&rows) {
            assert_eq!(row.conflicts as usize, bank_conflicts(ws, 16, BankMap::Interleave));
        }
    }
}
