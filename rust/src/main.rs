//! `ltrf` — CLI for the LTRF reproduction.
//!
//! Every table/figure in the paper's evaluation is a subcommand; `all`
//! regenerates the full set (EXPERIMENTS.md records the outputs). Flags
//! parse through [`ltrf::cli`]: each subcommand declares its accepted
//! set, and the shared knobs (`--jobs`, `--backend`, `--sim-threads`,
//! `--json`, `--store`) are single definitions that behave identically
//! everywhere.

use ltrf::cli;
use ltrf::coordinator::engine::{run_point, CfgTweaks, Engine};
use ltrf::coordinator::experiments::{self as exp, ExperimentContext};
use ltrf::coordinator::{designs, frontier, service, MemoStore};
use ltrf::report::Table;
use ltrf::sim::SimBackend;
use ltrf::workloads::suite;
use std::path::{Path, PathBuf};

const USAGE: &str = "\
ltrf — Latency-Tolerant Register File reproduction

USAGE: ltrf <command> [flags]

Experiment commands (regenerate paper tables/figures):
  table1      Required RF capacity for max TLP
  table2      RF design points (tech/banks/network)
  fig2        On-chip storage across GPU generations
  fig3        IPC with ideal / TFET 8x register files
  fig4        Register cache hit rates (RFC / SHRF)
  fig6        Bank-conflict distribution in register-intervals
  fig14       Overall IPC on configs #6 and #7
  fig15       Maximum tolerable MRF latency per design
  fig16       Conflicts: LTRF vs LTRF_conf x {8,16,32} regs
  fig17       IPC vs latency x regs-per-interval
  fig18       IPC vs latency x active warps
  table4      Real vs optimal register-interval length
  fig19       LTRF vs strand-based SW caching (SHRF)
  fig20       Tolerable latency vs warps/SM
  overheads   §5.3 code-size/storage/area/power overheads
  ablations   Design-choice ablations (refetch overlap, xbar, banking)
  ltrfplus    LTRF vs LTRF+ liveness-filtering traffic (§3.2)
  headline    Abstract claim: LTRF_conf on config #7
  all         Everything above
All experiment commands accept [--quick] [--csv DIR] [--sms N] [--jobs N]
[--backend B] [--sim-threads N] [--store DIR] [--json] [--engine-stats].
With --store DIR, simulated points persist in a cross-run memo store and
identical reruns answer from disk without simulating.

Auto-tuner:
  frontier [--quick] [--capacities LIST] [--banks LIST] [--threshold F]
           [--emit-requests DIR]
              Pareto-frontier search over the design registry x latency x
              capacity x bank-count space. Scores every candidate at its
              maximum tolerable latency and prints the non-dominated set
              on IPC (up) vs power (down) vs capacity (up); accepts the
              shared experiment flags, so --store makes re-searches free.
              With --emit-requests DIR, write sweep-service request files
              covering the search grid (pre-warm via `sweep serve`) and
              exit without searching.

Batch sweep service:
  sweep submit <file.json> [--spool DIR]
              Validate a sweep-request file (workloads x designs x
              latencies cross-product as JSON; see README) and copy it
              into the spool
  sweep serve [--spool DIR] [--store DIR] [--jobs N] [--once]
              Process spooled requests on the work-stealing executor with
              fair sharing, streaming results to <spool>/results/*.jsonl;
              --once drains the spool and exits (CI), otherwise polls

Tool commands:
  compile <file.ltrf> [--regs N] [--banks N] [--renumber] [--explain]
              Compile + dump intervals; --explain prints the pass DAG,
              per-pass wall time, and analysis-cache hits (cold + warm)
  run <workload> [--hierarchy BL|RFC|SHRF|LTRF|LTRF_conf|CARF] [--latency F]
                 [--capacity WARP_REGS] [--renumber]  Simulate one workload
  designs [--sweep]
              List the design registry (every registered RF policy); with
              --sweep, simulate one workload across all of them and print
              IPC + traffic per policy
  workloads   List the benchmark suite
  trace <workload> [--cycles N] [--hierarchy H] [--latency F]
              Per-cycle warp-state timeline (debugging)

Verification commands:
  fuzz [--seed-range A..B] [--corpus DIR] [--jobs N] [--shrink-budget N]
              Differential scenario fuzzing: replay the corpus, generate
              seeded kernels, and check the cross-config oracles; failures
              shrink to minimal .ltrf repros under corpus/regressions/
  snapshot (--check | --bless) [--golden PATH] [--quick] [--jobs N]
              Golden-stats harness: --bless captures the workload x config
              counter snapshot; --check diffs the current simulator
              against the committed golden file (exit 1 on drift, exit 3
              while the committed golden is still empty/unarmed)
  bench [--json PATH] [--quick] [--sim-threads N] [--iters N]
              Simulator throughput trajectory: simulated-cycles/sec and
              fig14-matrix wall time for both backends, written as
              machine-readable JSON (default BENCH_sim.json)

Shared flags:
  --quick       5-workload subset, smaller grids
  --csv DIR     also write each table as CSV
  --sms N       simulated SM count (default 1)
  --jobs N      parallel simulation workers (default: all cores)
  --backend B   simulator backend: reference | parallel (default reference)
  --sim-threads N  step-phase threads for the parallel backend (default 1)
  --store DIR   cross-run memo store (persist + reuse simulated points)
  --json        print tables as JSON objects instead of ascii
  --engine-stats  print job-matrix / cache statistics after a run
";

fn die(msg: &str) -> ! {
    eprintln!("{msg}");
    std::process::exit(2);
}

fn parse_or_die(cmd: &str, args: &[String], spec: &[cli::FlagSpec]) -> cli::Parsed {
    cli::parse(cmd, args, spec).unwrap_or_else(|e| die(&e))
}

fn opt_parsed<T: std::str::FromStr>(p: &cli::Parsed, name: &str) -> Option<T> {
    p.parsed_opt(name).unwrap_or_else(|e| die(&e))
}

fn opt_or<T: std::str::FromStr>(p: &cli::Parsed, name: &str, default: T) -> T {
    opt_parsed(p, name).unwrap_or(default)
}

fn ctx_from(p: &cli::Parsed) -> ExperimentContext {
    ExperimentContext {
        quick: p.flag("--quick"),
        csv_dir: p.opt("--csv").map(PathBuf::from),
        num_sms: opt_or(p, "--sms", 1),
        jobs: opt_or(p, "--jobs", 0),
    }
}

/// Simulator-backend selection (`run` / `snapshot` / the experiment
/// engine's default tweaks). The knobs exist so CI can diff the backends
/// against each other; the default is the reference backend.
fn tweaks_from(p: &cli::Parsed) -> CfgTweaks {
    let mut tw = CfgTweaks::NONE;
    if let Some(name) = p.opt("--backend") {
        match SimBackend::by_name(name) {
            Some(b) => tw.backend = Some(b),
            None => die(&format!("unknown --backend `{name}` (expected: reference | parallel)")),
        }
    }
    tw.sim_threads = opt_parsed(p, "--sim-threads");
    tw
}

/// Engine shared by one experiment invocation: `--backend`/`--sim-threads`
/// become its default tweaks, `--store DIR` attaches the cross-run memo
/// store consulted before any simulation is scheduled.
fn engine_for(p: &cli::Parsed, jobs: usize) -> Engine {
    let mut eng = Engine::new(jobs);
    eng.set_default_tweaks(tweaks_from(p));
    if let Some(dir) = p.opt("--store") {
        eng.set_store(MemoStore::open(Path::new(dir)));
    }
    eng
}

/// End-of-run bookkeeping: `--engine-stats` telemetry, then persist any
/// newly simulated points into the memo store.
fn finish(p: &cli::Parsed, eng: &mut Engine) {
    if p.flag("--engine-stats") {
        eprintln!("{}", eng.summary());
    }
    if let Err(e) = eng.flush_store() {
        eprintln!("warning: memo store save failed: {e}");
    }
}

fn emit(t: &Table, json: bool) {
    if json {
        println!("{}", t.to_json());
    } else {
        println!("{}", t.render());
    }
}

const EXPERIMENT_FLAGS: &[cli::FlagSpec] = &[
    cli::QUICK,
    cli::CSV,
    cli::SMS,
    cli::JOBS,
    cli::BACKEND,
    cli::SIM_THREADS,
    cli::STORE,
    cli::JSON,
    cli::ENGINE_STATS,
];

fn experiment(cmd: &str, rest: &[String]) {
    let p = parse_or_die(cmd, rest, EXPERIMENT_FLAGS);
    let ctx = ctx_from(&p);
    let mut eng = engine_for(&p, ctx.jobs);
    let json = p.flag("--json");
    match cmd {
        "table1" => emit(&exp::table1(&ctx, &mut eng), json),
        "table2" => emit(&exp::table2_table(&ctx, &mut eng), json),
        "fig2" => emit(&exp::fig2(&ctx, &mut eng), json),
        "fig3" => emit(&exp::fig3(&ctx, &mut eng), json),
        "fig4" => emit(&exp::fig4(&ctx, &mut eng), json),
        "fig6" => emit(&exp::fig6(&ctx, &mut eng), json),
        "fig14" => exp::fig14(&ctx, &mut eng).iter().for_each(|t| emit(t, json)),
        "fig15" => emit(&exp::fig15(&ctx, &mut eng), json),
        "fig16" => exp::fig16(&ctx, &mut eng).iter().for_each(|t| emit(t, json)),
        "fig17" => emit(&exp::fig17(&ctx, &mut eng), json),
        "fig18" => emit(&exp::fig18(&ctx, &mut eng), json),
        "table4" => emit(&exp::table4(&ctx, &mut eng), json),
        "fig19" => emit(&exp::fig19(&ctx, &mut eng), json),
        "fig20" => emit(&exp::fig20(&ctx, &mut eng), json),
        "overheads" => emit(&exp::overheads(&ctx, &mut eng), json),
        "ablations" => exp::ablations(&ctx, &mut eng).iter().for_each(|t| emit(t, json)),
        "ltrfplus" => emit(&exp::ltrf_plus(&ctx, &mut eng), json),
        "headline" => {
            let (imp, t) = exp::headline(&ctx, &mut eng);
            emit(&t, json);
            println!(
                "LTRF_conf on config #7 improves mean IPC by {:.1}% (paper: 34%)",
                imp * 100.0
            );
        }
        "all" => {
            let (tables, imp) = exp::all_tables(&ctx, &mut eng);
            tables.iter().for_each(|t| emit(t, json));
            println!("Headline: +{:.1}% mean IPC (paper: +34%)", imp * 100.0);
        }
        _ => unreachable!("experiment dispatch covers every listed command"),
    }
    finish(&p, &mut eng);
}

/// Parse a comma-separated positive-integer list flag.
fn usize_list(p: &cli::Parsed, name: &str) -> Option<Vec<usize>> {
    p.opt(name).map(|raw| {
        raw.split(',')
            .map(|s| match s.trim().parse::<usize>() {
                Ok(n) if n > 0 => n,
                _ => die(&format!("{name} expects positive integers, got `{s}`")),
            })
            .collect()
    })
}

fn frontier_cmd(rest: &[String]) {
    const CAPACITIES: cli::FlagSpec = cli::opt(
        "--capacities",
        "LIST",
        "MRF capacities probed, warp-regs (default 2048,4096,8192,16384)",
    );
    const BANKS: cli::FlagSpec =
        cli::opt("--banks", "LIST", "extra MRF bank counts probed per design point");
    const THRESHOLD: cli::FlagSpec =
        cli::opt("--threshold", "F", "IPC retention threshold (default 0.95)");
    const EMIT_REQUESTS: cli::FlagSpec = cli::opt(
        "--emit-requests",
        "DIR",
        "write sweep-service request files covering the search grid and exit",
    );
    let p = parse_or_die(
        "frontier",
        rest,
        &[
            cli::QUICK,
            cli::CSV,
            cli::JOBS,
            cli::BACKEND,
            cli::SIM_THREADS,
            cli::STORE,
            cli::JSON,
            cli::ENGINE_STATS,
            CAPACITIES,
            BANKS,
            THRESHOLD,
            EMIT_REQUESTS,
        ],
    );
    let mut space = frontier::FrontierSpace::new(p.flag("--quick"));
    if let Some(caps) = usize_list(&p, "--capacities") {
        space.capacities = caps;
    }
    if let Some(banks) = usize_list(&p, "--banks") {
        space.banks = banks;
    }
    if let Some(t) = opt_parsed::<f64>(&p, "--threshold") {
        if !t.is_finite() || t <= 0.0 || t > 1.0 {
            die(&format!("--threshold must be in (0, 1], got {t}"));
        }
        space.threshold = t;
    }
    if let Some(dir) = p.opt("--emit-requests") {
        let files = frontier::emit_requests(&space, Path::new(dir)).unwrap_or_else(|e| die(&e));
        println!("frontier: wrote {} sweep request files to {dir}", files.len());
        for f in &files {
            println!("  {}", f.display());
        }
        return;
    }
    let mut eng = engine_for(&p, opt_or(&p, "--jobs", 0));
    let report = frontier::search(&mut eng, &space);
    let json = p.flag("--json");
    let tables = report.tables();
    for t in &tables {
        emit(t, json);
    }
    if let Some(dir) = p.opt("--csv") {
        let dir = PathBuf::from(dir);
        for (t, name) in tables.iter().zip(["frontier", "frontier_candidates"]) {
            t.write_csv(&dir, name)
                .unwrap_or_else(|e| die(&format!("cannot write {name}.csv: {e}")));
        }
    }
    println!("{}", report.summary());
    finish(&p, &mut eng);
}

fn sweep_cmd(rest: &[String]) {
    const SPOOL: cli::FlagSpec =
        cli::opt("--spool", "DIR", "request spool directory (default sweeps)");
    const ONCE: cli::FlagSpec = cli::flag("--once", "drain the spool once and exit");
    let usage = "usage: ltrf sweep (serve [--spool DIR] [--store DIR] [--jobs N] [--once] \
                 | submit <file.json> [--spool DIR])";
    let Some(sub) = rest.first().map(|s| s.as_str()) else { die(usage) };
    match sub {
        "serve" => {
            let p = parse_or_die(
                "sweep serve",
                &rest[1..],
                &[SPOOL, cli::STORE, cli::JOBS, ONCE],
            );
            let spool = PathBuf::from(p.opt("--spool").unwrap_or("sweeps"));
            let store = p.opt("--store").map(PathBuf::from);
            let jobs = opt_or(&p, "--jobs", 0usize);
            if let Err(e) = service::serve(&spool, store.as_deref(), jobs, p.flag("--once")) {
                eprintln!("{e}");
                std::process::exit(1);
            }
        }
        "submit" => {
            let p = parse_or_die("sweep submit", &rest[1..], &[SPOOL]);
            let Some(file) = p.positionals.first() else {
                die("usage: ltrf sweep submit <file.json> [--spool DIR]")
            };
            let spool = PathBuf::from(p.opt("--spool").unwrap_or("sweeps"));
            match service::submit(&spool, Path::new(file)) {
                Ok(msg) => println!("{msg}"),
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(1);
                }
            }
        }
        other => die(&format!("unknown sweep subcommand `{other}`\n{usage}")),
    }
}

fn fuzz_cmd(rest: &[String]) {
    let p = parse_or_die(
        "fuzz",
        rest,
        &[
            cli::opt("--seed-range", "A..B", "seed range (default 0..200)"),
            cli::opt("--corpus", "DIR", "scenario corpus directory"),
            cli::JOBS,
            cli::opt("--shrink-budget", "N", "max shrink iterations per failure"),
        ],
    );
    let range = p.opt("--seed-range").unwrap_or("0..200").to_string();
    let Some((a, b)) = range.split_once("..") else {
        die(&format!("bad --seed-range `{range}` (expected A..B)"));
    };
    let (Ok(seed_start), Ok(seed_end)) = (a.parse::<u64>(), b.parse::<u64>()) else {
        die(&format!("bad --seed-range `{range}` (expected A..B)"));
    };
    if seed_end <= seed_start {
        die(&format!("empty --seed-range `{range}`"));
    }
    let fuzz_opts = ltrf::scenario::FuzzOptions {
        seed_start,
        seed_end,
        jobs: opt_or(&p, "--jobs", 0),
        corpus_dir: p.opt("--corpus").map(PathBuf::from).unwrap_or_else(|| "corpus".into()),
        shrink_budget: opt_or(&p, "--shrink-budget", 400),
        ..Default::default()
    };
    let report = ltrf::scenario::run_fuzz(&fuzz_opts);
    println!("{}", report.summary());
    if !report.ok() {
        for f in &report.failures {
            eprintln!("\nFAIL [{}] {}", f.oracle, f.detail);
            if let Some(seed) = f.seed {
                eprintln!("  seed: {seed}");
            }
            if let Some(src) = &f.source {
                eprintln!("  source: {}", src.display());
            }
            match &f.repro_path {
                Some(p) => eprintln!("  shrunken repro: {}", p.display()),
                None => eprintln!("  minimized repro:\n{}", f.minimized),
            }
        }
        std::process::exit(1);
    }
}

fn snapshot_cmd(rest: &[String]) {
    let p = parse_or_die(
        "snapshot",
        rest,
        &[
            cli::flag("--check", "diff the simulator against the golden file"),
            cli::flag("--bless", "capture and write the golden file"),
            cli::opt("--golden", "PATH", "golden stats file (default corpus/golden/stats.tsv)"),
            cli::QUICK,
            cli::JOBS,
            cli::BACKEND,
            cli::SIM_THREADS,
        ],
    );
    let quick = p.flag("--quick");
    let jobs = opt_or(&p, "--jobs", 0usize);
    let backend_tweaks = tweaks_from(&p);
    let golden = p
        .opt("--golden")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(ltrf::scenario::snapshot::GOLDEN_PATH));
    if p.flag("--bless") {
        let snap = ltrf::scenario::snapshot::capture_tweaked(quick, jobs, backend_tweaks);
        if let Err(e) = snap.save(&golden) {
            eprintln!("{e}");
            std::process::exit(1);
        }
        println!("blessed {} keys into {}", snap.entries.len(), golden.display());
    } else if p.flag("--check") {
        // Exit code contract (tested in `scenario::snapshot`): 0 = match,
        // 1 = drift (or unreadable golden), 3 = missing/unarmed golden.
        let out = ltrf::scenario::snapshot::check_golden(&golden, || {
            ltrf::scenario::snapshot::capture_tweaked(quick, jobs, backend_tweaks)
        });
        if out.exit_code == 0 {
            println!("{}", out.message);
        } else {
            eprintln!("{}", out.message);
            std::process::exit(out.exit_code);
        }
    } else {
        die("usage: ltrf snapshot (--check | --bless) [--golden PATH] [--quick]");
    }
}

fn bench_cmd(rest: &[String]) {
    let p = parse_or_die(
        "bench",
        rest,
        &[
            cli::opt("--json", "PATH", "output path (default BENCH_sim.json)"),
            cli::QUICK,
            cli::SIM_THREADS,
            cli::opt("--iters", "N", "measurement iterations per entry"),
        ],
    );
    let quick = p.flag("--quick");
    let sim_threads = opt_or(&p, "--sim-threads", 4usize);
    let iters = opt_or(&p, "--iters", if quick { 1 } else { 3 });
    let opts = ltrf::bench::BenchOptions { quick, sim_threads, iters };
    let report = ltrf::bench::run_bench(&opts);
    for e in &report.entries {
        println!(
            "{:<16} {:>10} x{:<2} {:>10.3} ms  {:>14.0} cycles/s  {:>12.0} winst/s",
            e.name,
            e.backend,
            e.sim_threads,
            e.wall_seconds * 1e3,
            e.cycles_per_second(),
            e.winst_per_second()
        );
    }
    for e in &report.compile_entries {
        println!(
            "{:<16} {:>10}     {:>10.3} ms  {:>8} compiles  cache {}/{} hits/misses",
            e.name,
            e.mode,
            e.wall_seconds * 1e3,
            e.compiles,
            e.analysis_hits,
            e.analysis_misses
        );
    }
    for e in &report.store_entries {
        println!(
            "{:<16} {:>10}     {:>10.3} ms  {:>8} sims  store {}/{} hits/misses",
            e.name, e.mode, e.wall_seconds * 1e3, e.sims, e.store_hits, e.store_misses
        );
    }
    for e in &report.frontier_entries {
        println!(
            "{:<16} {:>10}     {:>10.3} ms  {:>8} sims  {} frontier points  store {}/{} hits/misses",
            e.name,
            e.mode,
            e.wall_seconds * 1e3,
            e.sims,
            e.frontier_points,
            e.store_hits,
            e.store_misses
        );
    }
    if let Some(s) = report.fig14_speedup() {
        println!("fig14 matrix: parallel x{} is {s:.2}x reference wall time", report.sim_threads);
    }
    if let Some(s) = report.replay_speedup() {
        println!(
            "replay hot loop: interval replay is {s:.2}x dense wall time (replay fast-forwards {}, cycles saved {})",
            report.epoch_replay_fast_forwards, report.epoch_replay_cycles_saved
        );
    }
    if let Some(s) = report.replay_mw_speedup() {
        println!(
            "replay hot loop (multi-warp): ensemble replay is {s:.2}x dense wall time (ensemble fast-forwards {}, cycles saved {})",
            report.epoch_replay_ensemble_fast_forwards, report.epoch_replay_ensemble_cycles_saved
        );
    }
    if let Some(s) = report.compile_warm_speedup() {
        println!("compile matrix: warm analysis cache is {s:.2}x cold wall time");
    }
    if let Some(s) = report.store_warm_speedup() {
        println!("store matrix: warm memo store is {s:.2}x cold wall time");
    }
    if let Some(s) = report.frontier_warm_speedup() {
        println!("frontier search: warm memo store is {s:.2}x cold wall time");
    }
    let path = p.opt("--json").map(PathBuf::from).unwrap_or_else(|| "BENCH_sim.json".into());
    if let Err(e) = std::fs::write(&path, report.to_json()) {
        eprintln!("cannot write {}: {e}", path.display());
        std::process::exit(1);
    }
    println!("wrote {}", path.display());
}

fn designs_cmd(rest: &[String]) {
    let p = parse_or_die(
        "designs",
        rest,
        &[
            cli::flag("--sweep", "simulate one workload across every registered policy"),
            cli::JOBS,
            cli::BACKEND,
            cli::SIM_THREADS,
            cli::STORE,
            cli::JSON,
            cli::ENGINE_STATS,
        ],
    );
    let json = p.flag("--json");
    let mut eng = engine_for(&p, opt_or(&p, "--jobs", 0));
    let mut t = Table::new(
        "Design registry — the canonical §6 policy comparison points",
        &["name", "hierarchy", "subgraphs", "compile mode", "latencies", "description"],
    );
    for pt in designs::REGISTRY {
        t.row(vec![
            pt.name.into(),
            pt.hierarchy.name().into(),
            if pt.hierarchy.uses_subgraphs() { "yes".into() } else { "no".into() },
            format!(
                "{:?}{}",
                pt.hierarchy.subgraph_mode(),
                if pt.renumber { " + renumber" } else { "" }
            ),
            pt.latency_factors.iter().map(|f| format!("{f:.1}x")).collect::<Vec<_>>().join(" "),
            pt.blurb.into(),
        ]);
    }
    emit(&t, json);
    if p.flag("--sweep") {
        // Sweep one workload across every registered policy so the
        // engine's design-point coverage reaches the registry size
        // (`--engine-stats` prints the ratio; CI greps it).
        let spec = suite::workload_by_name("kmeans").expect("kmeans");
        let mut s = Table::new(
            "Registry sweep — kmeans @ 1.0x",
            &["name", "IPC", "RF$ accesses", "MRF accesses", "regs moved", "power vs BL"],
        );
        for (_, dut) in designs::all_points(2048) {
            eng.request(spec, &dut, 1.0);
        }
        eng.execute();
        for (name, dut) in designs::all_points(2048) {
            let st = eng.point(spec, &dut, 1.0);
            let model = ltrf::sim::model_for(dut.hierarchy);
            let tr = model.traffic(&st);
            let power = model.power(&st, 1.0, ltrf::timing::Tech::HpSram).total();
            s.row(vec![
                name.into(),
                format!("{:.3}", st.ipc()),
                tr.cache_accesses.to_string(),
                tr.mrf_accesses.to_string(),
                tr.regs_moved.to_string(),
                format!("{:.2}", power),
            ]);
        }
        emit(&s, json);
    }
    finish(&p, &mut eng);
}

fn workloads_cmd(rest: &[String]) {
    let p = parse_or_die("workloads", rest, &[cli::JSON]);
    let mut t = Table::new(
        "Benchmark suite",
        &["name", "class", "regs/thread (Maxwell)", "regs/thread (Fermi)"],
    );
    for w in suite::suite() {
        t.row(vec![
            w.name.into(),
            format!("{:?}", w.class),
            w.regs_maxwell.to_string(),
            w.regs_fermi.to_string(),
        ]);
    }
    emit(&t, p.flag("--json"));
}

fn compile_cmd(rest: &[String]) {
    let p = parse_or_die(
        "compile",
        rest,
        &[
            cli::opt("--regs", "N", "registers per interval (default 16)"),
            cli::opt("--banks", "N", "register-file bank count"),
            cli::flag("--renumber", "apply the §4 bank-aware renumbering pass"),
            cli::flag("--explain", "print the pass DAG, timings, and cache hits"),
        ],
    );
    let Some(path) = p.positionals.first() else {
        die("usage: ltrf compile <file.ltrf> [--regs N] [--banks N] [--renumber] [--explain]");
    };
    let n: usize = opt_or(&p, "--regs", 16);
    let src = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("cannot read {path}: {e}");
        std::process::exit(1);
    });
    let kernel = ltrf::ir::parser::parse(&src).unwrap_or_else(|e| {
        eprintln!("parse error: {e:#}");
        std::process::exit(1);
    });
    let mut opts = ltrf::compiler::CompileOptions::ltrf(n);
    opts.renumber = p.flag("--renumber");
    if let Some(b) = opt_parsed(&p, "--banks") {
        opts.num_banks = b;
    }
    let mgr = ltrf::compiler::PassManager::new();
    let (ck, trace) = match mgr.compile_traced(&kernel, opts) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("compile error: {e}");
            std::process::exit(1);
        }
    };
    if p.flag("--explain") {
        println!(
            "pass DAG ({:?} mode{}):",
            opts.mode,
            if opts.renumber { " + renumber" } else { "" }
        );
        for (node, deps) in ltrf::compiler::passes::dag(&opts) {
            if deps.is_empty() {
                println!("  {node}");
            } else {
                println!("  {node}  <-  {}", deps.join(", "));
            }
        }
        println!(
            "\ncold compile of fingerprint {} ({:.1} us total):",
            trace.input,
            trace.total.as_secs_f64() * 1e6
        );
        println!("  {:<14} {:>12} {:>7}", "pass", "wall", "cache");
        for tp in &trace.passes {
            println!(
                "  {:<14} {:>9.1} us {:>7}",
                tp.pass.name(),
                tp.wall.as_secs_f64() * 1e6,
                if tp.cached { "hit" } else { "miss" }
            );
        }
        let (_, warm) = mgr.compile_traced(&kernel, opts).expect("warm recompile");
        println!(
            "warm recompile: {}/{} passes served from the analysis cache in {:.1} us",
            warm.cache_hits(),
            warm.passes.len(),
            warm.total.as_secs_f64() * 1e6
        );
        println!(
            "output kernel fingerprint {} ({})\n",
            trace.output,
            if trace.output == trace.input {
                "unchanged: no kernel-mutating pass fired"
            } else {
                "changed: splits/renumbering invalidate downstream analyses"
            }
        );
    }
    println!("{}", ck.kernel.display());
    let mut t = Table::new(
        format!("register-intervals (N={n})"),
        &["interval", "header", "blocks", "working set", "bank conflicts"],
    );
    for iv in &ck.intervals.intervals {
        t.row(vec![
            iv.id.to_string(),
            ck.kernel.blocks[iv.header].label.clone(),
            iv.blocks.len().to_string(),
            format!("{:?}", iv.working_set),
            ltrf::compiler::renumber::bank_conflicts(
                &iv.working_set,
                opts.num_banks,
                opts.bank_map,
            )
            .to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "code-size overhead: {:.1}% (bit-vectors), conflict-free prefetches: {:.0}%",
        ck.code_size_overhead(false) * 100.0,
        ck.conflict_free_fraction() * 100.0
    );
}

fn run_cmd(rest: &[String]) {
    let p = parse_or_die(
        "run",
        rest,
        &[
            cli::opt("--hierarchy", "H", "policy name from the design registry (default LTRF)"),
            cli::opt("--latency", "F", "MRF latency factor (default 1.0)"),
            cli::opt("--capacity", "N", "RF capacity in warp-registers (default 2048)"),
            cli::flag("--renumber", "compile with the §4 renumbering pass"),
            cli::SMS,
            cli::BACKEND,
            cli::SIM_THREADS,
        ],
    );
    let Some(name) = p.positionals.first() else {
        die("usage: ltrf run <workload> [flags]");
    };
    let Some(spec) = suite::workload_by_name(name) else {
        eprintln!("unknown workload `{name}` (see `ltrf workloads`)");
        std::process::exit(1);
    };
    let hname = p.opt("--hierarchy").unwrap_or("LTRF");
    let Some(policy) = designs::by_name(hname) else {
        eprintln!("unknown hierarchy `{hname}` (see `ltrf designs`)");
        std::process::exit(1);
    };
    let hierarchy = policy.hierarchy;
    let factor: f64 = opt_or(&p, "--latency", 1.0);
    let mut dut = policy.dut();
    dut.renumber = policy.renumber || p.flag("--renumber");
    if let Some(cap) = opt_parsed(&p, "--capacity") {
        dut = dut.with_capacity(cap);
    }
    dut.num_sms = opt_or(&p, "--sms", 1);
    let st = run_point(spec, &dut, factor, tweaks_from(&p), None);
    println!(
        "{name} on {} @ {factor}x: IPC {:.3} ({} insts / {} cycles)",
        hierarchy.name(),
        st.ipc(),
        st.instructions,
        st.cycles
    );
    if st.hit_cycle_cap != 0 {
        println!("  WARNING: truncated at the max_cycles cap — not a converged result");
    }
    println!(
        "  L1 hit {:.1}%  RFC hit {:.1}%  prefetches {} ({} regs)  activations {}  MRF acc reduction {:.1}x",
        st.l1_hit_rate() * 100.0,
        st.rfc_hit_rate() * 100.0,
        st.prefetch_ops,
        st.prefetch_regs,
        st.activations,
        st.mrf_access_reduction()
    );
    println!(
        "  epoch core: commit phases skipped {}  wheel rollovers {}  replay fast-forwards {} (cycles saved {})",
        st.commit_phases_skipped,
        st.event_wheel_rollovers,
        st.replay_fast_forwards,
        st.replay_cycles_saved
    );
    println!(
        "  replay engine: ensemble fast-forwards {} (cycles saved {})  cell drops mem/divergence/rotation {}/{}/{}",
        st.replay_ensemble_fast_forwards,
        st.replay_ensemble_cycles_saved,
        st.replay_cell_drops_mem,
        st.replay_cell_drops_divergence,
        st.replay_cell_drops_rotation
    );
}

fn trace_cmd(rest: &[String]) {
    let p = parse_or_die(
        "trace",
        rest,
        &[
            cli::opt("--cycles", "N", "max cycles to trace (default 200)"),
            cli::opt("--hierarchy", "H", "policy name from the design registry"),
            cli::opt("--latency", "F", "MRF latency factor (default 6.3)"),
        ],
    );
    let Some(name) = p.positionals.first() else {
        die("usage: ltrf trace <workload> [--cycles N]");
    };
    let Some(spec) = suite::workload_by_name(name) else {
        eprintln!("unknown workload `{name}`");
        std::process::exit(1);
    };
    let hierarchy = p
        .opt("--hierarchy")
        .and_then(designs::by_name)
        .map(|pt| pt.hierarchy)
        .unwrap_or(ltrf::sim::HierarchyKind::Ltrf { plus: true });
    let factor: f64 = opt_or(&p, "--latency", 6.3);
    let max: u64 = opt_or(&p, "--cycles", 200);
    let cfg = ltrf::sim::SimConfig {
        // A cycle-by-cycle trace wants dense stepping; replay would
        // fast-forward steady-state windows out of the printout.
        replay: false,
        ..ltrf::sim::SimConfig::with_hierarchy(hierarchy)
            .with_latency_factor(factor)
            .normalize_capacity()
    };
    let kernel = ltrf::workloads::gen::build(spec);
    let ck = ltrf::compiler::compile(&kernel, ltrf::sim::gpu::compile_options(&cfg, true));
    let resident = cfg.resident_warps(ck.kernel.num_regs);
    let mut shared = ltrf::sim::memsys::SharedMem::new(cfg.mem);
    let mut sm = ltrf::sim::sm::SmSim::new(&cfg, &ck, resident, 0);
    println!(
        "trace: {name} on {} @{factor}x, {resident} resident warps (A=active P=prefetch M=mem W=wait .=not started F=finished)",
        hierarchy.name()
    );
    let mut now = 0u64;
    while now < max && !sm.done() {
        let hint = sm.step(now, &mut ltrf::sim::sm::MemPort::Inline(&mut shared), u64::MAX);
        let line: String = (0..resident.min(32))
            .map(|w| match sm.warp_state(w) {
                ltrf::sim::warp::WarpState::Active => 'A',
                ltrf::sim::warp::WarpState::Prefetching { .. } => 'P',
                ltrf::sim::warp::WarpState::Refetching { .. } => 'p',
                ltrf::sim::warp::WarpState::PendingMem { .. } => 'M',
                ltrf::sim::warp::WarpState::WaitActivate => 'W',
                ltrf::sim::warp::WarpState::NotStarted => '.',
                ltrf::sim::warp::WarpState::Finished => 'F',
            })
            .collect();
        println!(
            "{now:>6} [{line}] issued={} prefetches={}",
            sm.stats.instructions, sm.stats.prefetch_ops
        );
        now = hint.max(now + 1);
    }
    println!(
        "\n{} instructions in {now} cycles (IPC {:.3})",
        sm.stats.instructions,
        sm.stats.instructions as f64 / now.max(1) as f64
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let rest = &args[1..];
    match cmd.as_str() {
        "table1" | "table2" | "fig2" | "fig3" | "fig4" | "fig6" | "fig14" | "fig15" | "fig16"
        | "fig17" | "fig18" | "table4" | "fig19" | "fig20" | "overheads" | "ablations"
        | "ltrfplus" | "headline" | "all" => experiment(cmd.as_str(), rest),
        "frontier" => frontier_cmd(rest),
        "sweep" => sweep_cmd(rest),
        "fuzz" => fuzz_cmd(rest),
        "snapshot" => snapshot_cmd(rest),
        "bench" => bench_cmd(rest),
        "designs" => designs_cmd(rest),
        "workloads" => workloads_cmd(rest),
        "compile" => compile_cmd(rest),
        "run" => run_cmd(rest),
        "trace" => trace_cmd(rest),
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command `{other}`\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}
