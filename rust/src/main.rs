//! `ltrf` — CLI for the LTRF reproduction.
//!
//! Every table/figure in the paper's evaluation is a subcommand; `all`
//! regenerates the full set (EXPERIMENTS.md records the outputs).

use ltrf::coordinator::designs;
use ltrf::coordinator::engine::{run_point, two_phase, CfgTweaks, Engine};
use ltrf::coordinator::experiments::{self as exp, ExperimentContext};
use ltrf::report::Table;
use ltrf::sim::SimBackend;
use ltrf::workloads::suite;
use std::path::PathBuf;

const USAGE: &str = "\
ltrf — Latency-Tolerant Register File reproduction

USAGE: ltrf <command> [flags]

Experiment commands (regenerate paper tables/figures):
  table1      Required RF capacity for max TLP
  table2      RF design points (tech/banks/network)
  fig2        On-chip storage across GPU generations
  fig3        IPC with ideal / TFET 8x register files
  fig4        Register cache hit rates (RFC / SHRF)
  fig6        Bank-conflict distribution in register-intervals
  fig14       Overall IPC on configs #6 and #7
  fig15       Maximum tolerable MRF latency per design
  fig16       Conflicts: LTRF vs LTRF_conf x {8,16,32} regs
  fig17       IPC vs latency x regs-per-interval
  fig18       IPC vs latency x active warps
  table4      Real vs optimal register-interval length
  fig19       LTRF vs strand-based SW caching (SHRF)
  fig20       Tolerable latency vs warps/SM
  overheads   §5.3 code-size/storage/area/power overheads
  ablations   Design-choice ablations (refetch overlap, xbar, banking)
  ltrfplus    LTRF vs LTRF+ liveness-filtering traffic (§3.2)
  headline    Abstract claim: LTRF_conf on config #7
  all         Everything above

Tool commands:
  compile <file.ltrf> [--regs N] [--banks N] [--renumber] [--explain]
              Compile + dump intervals; --explain prints the pass DAG,
              per-pass wall time, and analysis-cache hits (cold + warm)
  run <workload> [--hierarchy BL|RFC|SHRF|LTRF|LTRF_conf|CARF] [--latency F]
                 [--capacity WARP_REGS] [--renumber]  Simulate one workload
  designs [--sweep]
              List the design registry (every registered RF policy); with
              --sweep, simulate one workload across all of them and print
              IPC + traffic per policy
  workloads   List the benchmark suite
  trace <workload> [--cycles N] [--hierarchy H] [--latency F]
              Per-cycle warp-state timeline (debugging)

Verification commands:
  fuzz [--seed-range A..B] [--corpus DIR] [--jobs N] [--shrink-budget N]
              Differential scenario fuzzing: replay the corpus, generate
              seeded kernels, and check the cross-config oracles; failures
              shrink to minimal .ltrf repros under corpus/regressions/
  snapshot (--check | --bless) [--golden PATH] [--quick] [--jobs N]
              Golden-stats harness: --bless captures the workload x config
              counter snapshot; --check diffs the current simulator
              against the committed golden file (keyed diff on drift)
  bench [--json PATH] [--quick] [--sim-threads N] [--iters N]
              Simulator throughput trajectory: simulated-cycles/sec and
              fig14-matrix wall time for both backends, written as
              machine-readable JSON (default BENCH_sim.json)

Flags:
  --quick       5-workload subset, smaller grids
  --csv DIR     also write each table as CSV
  --sms N       simulated SM count (default 1)
  --jobs N      parallel simulation workers (default: all cores)
  --backend B   simulator backend: reference | parallel (default reference)
  --sim-threads N  step-phase threads for the parallel backend (default 1)
  --engine-stats  print job-matrix / cache statistics after a run
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let flag = |name: &str| args.iter().any(|a| a == name);
    let opt = |name: &str| -> Option<String> {
        args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
    };

    let ctx = ExperimentContext {
        quick: flag("--quick"),
        csv_dir: opt("--csv").map(PathBuf::from),
        num_sms: opt("--sms").and_then(|s| s.parse().ok()).unwrap_or(1),
        jobs: opt("--jobs").and_then(|s| s.parse().ok()).unwrap_or(0),
    };

    // Simulator-backend selection (`run` / `snapshot` / `bench`). The
    // experiment drivers always use the default backend; the knobs exist
    // so CI can diff the backends against each other.
    let backend_tweaks = {
        let mut tw = CfgTweaks::NONE;
        if let Some(name) = opt("--backend") {
            match SimBackend::by_name(&name) {
                Some(b) => tw.backend = Some(b),
                None => {
                    eprintln!("unknown --backend `{name}` (expected: reference | parallel)");
                    std::process::exit(2);
                }
            }
        }
        tw.sim_threads = opt("--sim-threads").and_then(|s| s.parse().ok());
        tw
    };

    let print = |t: &Table| println!("{}", t.render());
    let print_all = |ts: &[Table]| ts.iter().for_each(|t| println!("{}", t.render()));

    // Every experiment command shares one engine: figures declare their
    // simulation points into its job matrix (planning pass), the matrix
    // runs deduplicated on the work-stealing executor, then the figures
    // render from the result set.
    let mut eng = Engine::new(ctx.jobs);
    let engine_stats = flag("--engine-stats");

    macro_rules! finish {
        () => {
            if engine_stats {
                eprintln!("{}", eng.summary());
            }
        };
    }

    match cmd {
        "table1" => {
            print(&two_phase(&ctx, &mut eng, exp::table1));
            finish!();
        }
        "table2" => {
            print(&two_phase(&ctx, &mut eng, exp::table2_table));
            finish!();
        }
        "fig2" => {
            print(&two_phase(&ctx, &mut eng, exp::fig2));
            finish!();
        }
        "fig3" => {
            print(&two_phase(&ctx, &mut eng, exp::fig3));
            finish!();
        }
        "fig4" => {
            print(&two_phase(&ctx, &mut eng, exp::fig4));
            finish!();
        }
        "fig6" => {
            print(&two_phase(&ctx, &mut eng, exp::fig6));
            finish!();
        }
        "fig14" => {
            print_all(&two_phase(&ctx, &mut eng, exp::fig14));
            finish!();
        }
        "fig15" => {
            print(&two_phase(&ctx, &mut eng, exp::fig15));
            finish!();
        }
        "fig16" => {
            print_all(&two_phase(&ctx, &mut eng, exp::fig16));
            finish!();
        }
        "fig17" => {
            print(&two_phase(&ctx, &mut eng, exp::fig17));
            finish!();
        }
        "fig18" => {
            print(&two_phase(&ctx, &mut eng, exp::fig18));
            finish!();
        }
        "table4" => {
            print(&two_phase(&ctx, &mut eng, exp::table4));
            finish!();
        }
        "fig19" => {
            print(&two_phase(&ctx, &mut eng, exp::fig19));
            finish!();
        }
        "fig20" => {
            print(&two_phase(&ctx, &mut eng, exp::fig20));
            finish!();
        }
        "overheads" => {
            print(&two_phase(&ctx, &mut eng, exp::overheads));
            finish!();
        }
        "ablations" => {
            print_all(&two_phase(&ctx, &mut eng, exp::ablations));
            finish!();
        }
        "ltrfplus" => {
            print(&two_phase(&ctx, &mut eng, exp::ltrf_plus));
            finish!();
        }
        "headline" => {
            let (imp, t) = two_phase(&ctx, &mut eng, exp::headline);
            print(&t);
            println!(
                "LTRF_conf on config #7 improves mean IPC by {:.1}% (paper: 34%)",
                imp * 100.0
            );
            finish!();
        }
        "all" => {
            let (tables, imp) = two_phase(&ctx, &mut eng, exp::all_tables);
            print_all(&tables);
            println!("Headline: +{:.1}% mean IPC (paper: +34%)", imp * 100.0);
            finish!();
        }
        "fuzz" => {
            let range = opt("--seed-range").unwrap_or_else(|| "0..200".into());
            let Some((a, b)) = range.split_once("..") else {
                eprintln!("bad --seed-range `{range}` (expected A..B)");
                std::process::exit(2);
            };
            let (Ok(seed_start), Ok(seed_end)) = (a.parse::<u64>(), b.parse::<u64>()) else {
                eprintln!("bad --seed-range `{range}` (expected A..B)");
                std::process::exit(2);
            };
            if seed_end <= seed_start {
                eprintln!("empty --seed-range `{range}`");
                std::process::exit(2);
            }
            let fuzz_opts = ltrf::scenario::FuzzOptions {
                seed_start,
                seed_end,
                jobs: ctx.jobs,
                corpus_dir: opt("--corpus").map(PathBuf::from).unwrap_or_else(|| "corpus".into()),
                shrink_budget: opt("--shrink-budget").and_then(|s| s.parse().ok()).unwrap_or(400),
                ..Default::default()
            };
            let report = ltrf::scenario::run_fuzz(&fuzz_opts);
            println!("{}", report.summary());
            if !report.ok() {
                for f in &report.failures {
                    eprintln!("\nFAIL [{}] {}", f.oracle, f.detail);
                    if let Some(seed) = f.seed {
                        eprintln!("  seed: {seed}");
                    }
                    if let Some(src) = &f.source {
                        eprintln!("  source: {}", src.display());
                    }
                    match &f.repro_path {
                        Some(p) => eprintln!("  shrunken repro: {}", p.display()),
                        None => eprintln!("  minimized repro:\n{}", f.minimized),
                    }
                }
                std::process::exit(1);
            }
        }
        "snapshot" => {
            let golden = opt("--golden")
                .map(PathBuf::from)
                .unwrap_or_else(|| PathBuf::from(ltrf::scenario::snapshot::GOLDEN_PATH));
            if flag("--bless") {
                let snap =
                    ltrf::scenario::snapshot::capture_tweaked(ctx.quick, ctx.jobs, backend_tweaks);
                if let Err(e) = snap.save(&golden) {
                    eprintln!("{e}");
                    std::process::exit(1);
                }
                println!("blessed {} keys into {}", snap.entries.len(), golden.display());
            } else if flag("--check") {
                let gold = match ltrf::scenario::snapshot::Snapshot::load(&golden) {
                    Ok(g) => g,
                    Err(e) => {
                        eprintln!("{e}\nrun `ltrf snapshot --bless` to create the golden file");
                        std::process::exit(1);
                    }
                };
                if gold.is_empty() {
                    println!(
                        "snapshot: {} has no entries yet — capture skipped (bless and commit \
                         it to arm the drift gate)",
                        golden.display()
                    );
                    return;
                }
                let current =
                    ltrf::scenario::snapshot::capture_tweaked(ctx.quick, ctx.jobs, backend_tweaks);
                let diffs = gold.diff_against(&current);
                if diffs.is_empty() {
                    println!(
                        "snapshot OK: {} keys match {}",
                        current.entries.len(),
                        golden.display()
                    );
                } else {
                    eprintln!("snapshot DRIFT against {}:", golden.display());
                    for d in &diffs {
                        eprintln!("  {d}");
                    }
                    eprintln!(
                        "{} diffs; if intended, re-bless with `ltrf snapshot --bless`",
                        diffs.len()
                    );
                    std::process::exit(1);
                }
            } else {
                eprintln!("usage: ltrf snapshot (--check | --bless) [--golden PATH] [--quick]");
                std::process::exit(2);
            }
        }
        "bench" => {
            let sim_threads = opt("--sim-threads").and_then(|s| s.parse().ok()).unwrap_or(4);
            let iters = opt("--iters")
                .and_then(|s| s.parse().ok())
                .unwrap_or(if ctx.quick { 1 } else { 3 });
            let opts = ltrf::bench::BenchOptions { quick: ctx.quick, sim_threads, iters };
            let report = ltrf::bench::run_bench(&opts);
            for e in &report.entries {
                println!(
                    "{:<16} {:>10} x{:<2} {:>10.3} ms  {:>14.0} cycles/s  {:>12.0} winst/s",
                    e.name,
                    e.backend,
                    e.sim_threads,
                    e.wall_seconds * 1e3,
                    e.cycles_per_second(),
                    e.winst_per_second()
                );
            }
            for e in &report.compile_entries {
                println!(
                    "{:<16} {:>10}     {:>10.3} ms  {:>8} compiles  cache {}/{} hits/misses",
                    e.name,
                    e.mode,
                    e.wall_seconds * 1e3,
                    e.compiles,
                    e.analysis_hits,
                    e.analysis_misses
                );
            }
            if let Some(s) = report.fig14_speedup() {
                println!(
                    "fig14 matrix: parallel x{} is {s:.2}x reference wall time",
                    report.sim_threads
                );
            }
            if let Some(s) = report.compile_warm_speedup() {
                println!("compile matrix: warm analysis cache is {s:.2}x cold wall time");
            }
            let path = opt("--json").map(PathBuf::from).unwrap_or_else(|| "BENCH_sim.json".into());
            if let Err(e) = std::fs::write(&path, report.to_json()) {
                eprintln!("cannot write {}: {e}", path.display());
                std::process::exit(1);
            }
            println!("wrote {}", path.display());
        }
        "designs" => {
            let mut t = Table::new(
                "Design registry — the canonical §6 policy comparison points",
                &["name", "hierarchy", "subgraphs", "compile mode", "latencies", "description"],
            );
            for p in designs::REGISTRY {
                t.row(vec![
                    p.name.into(),
                    p.hierarchy.name().into(),
                    if p.hierarchy.uses_subgraphs() { "yes".into() } else { "no".into() },
                    format!(
                        "{:?}{}",
                        p.hierarchy.subgraph_mode(),
                        if p.renumber { " + renumber" } else { "" }
                    ),
                    p.latency_factors
                        .iter()
                        .map(|f| format!("{f:.1}x"))
                        .collect::<Vec<_>>()
                        .join(" "),
                    p.blurb.into(),
                ]);
            }
            print(&t);
            if flag("--sweep") {
                // Sweep one workload across every registered policy so the
                // engine's design-point coverage reaches the registry size
                // (`--engine-stats` prints the ratio; CI greps it).
                let spec = suite::workload_by_name("kmeans").expect("kmeans");
                let mut s = Table::new(
                    "Registry sweep — kmeans @ 1.0x",
                    &["name", "IPC", "RF$ accesses", "MRF accesses", "regs moved", "power vs BL"],
                );
                eng.plan_phase();
                for (_, dut) in designs::all_points(2048) {
                    eng.request(spec, &dut, 1.0);
                }
                eng.execute();
                for (name, dut) in designs::all_points(2048) {
                    let st = eng.stats(spec, &dut, 1.0);
                    let model = ltrf::sim::model_for(dut.hierarchy);
                    let tr = model.traffic(&st);
                    let power = model.power(&st, 1.0, ltrf::timing::Tech::HpSram).total();
                    s.row(vec![
                        name.into(),
                        format!("{:.3}", st.ipc()),
                        tr.cache_accesses.to_string(),
                        tr.mrf_accesses.to_string(),
                        tr.regs_moved.to_string(),
                        format!("{:.2}", power),
                    ]);
                }
                print(&s);
            }
            finish!();
        }
        "workloads" => {
            let mut t = Table::new(
                "Benchmark suite",
                &["name", "class", "regs/thread (Maxwell)", "regs/thread (Fermi)"],
            );
            for w in suite::suite() {
                t.row(vec![
                    w.name.into(),
                    format!("{:?}", w.class),
                    w.regs_maxwell.to_string(),
                    w.regs_fermi.to_string(),
                ]);
            }
            print(&t);
        }
        "compile" => {
            let Some(path) = args.get(1).filter(|a| !a.starts_with("--")) else {
                eprintln!(
                    "usage: ltrf compile <file.ltrf> [--regs N] [--banks N] [--renumber] [--explain]"
                );
                std::process::exit(2);
            };
            let n: usize = opt("--regs").and_then(|s| s.parse().ok()).unwrap_or(16);
            let src = std::fs::read_to_string(path).unwrap_or_else(|e| {
                eprintln!("cannot read {path}: {e}");
                std::process::exit(1);
            });
            let kernel = ltrf::ir::parser::parse(&src).unwrap_or_else(|e| {
                eprintln!("parse error: {e:#}");
                std::process::exit(1);
            });
            let mut opts = ltrf::compiler::CompileOptions::ltrf(n);
            opts.renumber = flag("--renumber");
            if let Some(raw) = opt("--banks") {
                match raw.parse() {
                    Ok(b) => opts.num_banks = b,
                    Err(_) => {
                        eprintln!("bad --banks `{raw}` (expected a bank count)");
                        std::process::exit(2);
                    }
                }
            }
            let mgr = ltrf::compiler::PassManager::new();
            let (ck, trace) = match mgr.compile_traced(&kernel, opts) {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("compile error: {e}");
                    std::process::exit(1);
                }
            };
            if flag("--explain") {
                println!(
                    "pass DAG ({:?} mode{}):",
                    opts.mode,
                    if opts.renumber { " + renumber" } else { "" }
                );
                for (node, deps) in ltrf::compiler::passes::dag(&opts) {
                    if deps.is_empty() {
                        println!("  {node}");
                    } else {
                        println!("  {node}  <-  {}", deps.join(", "));
                    }
                }
                println!(
                    "\ncold compile of fingerprint {} ({:.1} us total):",
                    trace.input,
                    trace.total.as_secs_f64() * 1e6
                );
                println!("  {:<14} {:>12} {:>7}", "pass", "wall", "cache");
                for p in &trace.passes {
                    println!(
                        "  {:<14} {:>9.1} us {:>7}",
                        p.pass.name(),
                        p.wall.as_secs_f64() * 1e6,
                        if p.cached { "hit" } else { "miss" }
                    );
                }
                let (_, warm) = mgr.compile_traced(&kernel, opts).expect("warm recompile");
                println!(
                    "warm recompile: {}/{} passes served from the analysis cache in {:.1} us",
                    warm.cache_hits(),
                    warm.passes.len(),
                    warm.total.as_secs_f64() * 1e6
                );
                println!(
                    "output kernel fingerprint {} ({})\n",
                    trace.output,
                    if trace.output == trace.input {
                        "unchanged: no kernel-mutating pass fired"
                    } else {
                        "changed: splits/renumbering invalidate downstream analyses"
                    }
                );
            }
            println!("{}", ck.kernel.display());
            let mut t = Table::new(
                format!("register-intervals (N={n})"),
                &["interval", "header", "blocks", "working set", "bank conflicts"],
            );
            for iv in &ck.intervals.intervals {
                t.row(vec![
                    iv.id.to_string(),
                    ck.kernel.blocks[iv.header].label.clone(),
                    iv.blocks.len().to_string(),
                    format!("{:?}", iv.working_set),
                    ltrf::compiler::renumber::bank_conflicts(
                        &iv.working_set,
                        opts.num_banks,
                        opts.bank_map,
                    )
                    .to_string(),
                ]);
            }
            print(&t);
            println!(
                "code-size overhead: {:.1}% (bit-vectors), conflict-free prefetches: {:.0}%",
                ck.code_size_overhead(false) * 100.0,
                ck.conflict_free_fraction() * 100.0
            );
        }
        "run" => {
            let Some(name) = args.get(1).filter(|a| !a.starts_with("--")) else {
                eprintln!("usage: ltrf run <workload> [flags]");
                std::process::exit(2);
            };
            let Some(spec) = suite::workload_by_name(name) else {
                eprintln!("unknown workload `{name}` (see `ltrf workloads`)");
                std::process::exit(1);
            };
            let hname = opt("--hierarchy").unwrap_or_else(|| "LTRF".into());
            let Some(policy) = designs::by_name(&hname) else {
                eprintln!("unknown hierarchy `{hname}` (see `ltrf designs`)");
                std::process::exit(1);
            };
            let hierarchy = policy.hierarchy;
            let factor: f64 = opt("--latency").and_then(|s| s.parse().ok()).unwrap_or(1.0);
            let mut dut = policy.dut();
            dut.renumber = policy.renumber || flag("--renumber");
            if let Some(cap) = opt("--capacity").and_then(|s| s.parse().ok()) {
                dut = dut.with_capacity(cap);
            }
            dut.num_sms = ctx.num_sms;
            let st = run_point(spec, &dut, factor, backend_tweaks, None);
            println!(
                "{name} on {} @ {factor}x: IPC {:.3} ({} insts / {} cycles)",
                hierarchy.name(),
                st.ipc(),
                st.instructions,
                st.cycles
            );
            if st.hit_cycle_cap != 0 {
                println!("  WARNING: truncated at the max_cycles cap — not a converged result");
            }
            println!(
                "  L1 hit {:.1}%  RFC hit {:.1}%  prefetches {} ({} regs)  activations {}  MRF acc reduction {:.1}x",
                st.l1_hit_rate() * 100.0,
                st.rfc_hit_rate() * 100.0,
                st.prefetch_ops,
                st.prefetch_regs,
                st.activations,
                st.mrf_access_reduction()
            );
            println!(
                "  epoch core: commit phases skipped {}  wheel rollovers {}",
                st.commit_phases_skipped, st.event_wheel_rollovers
            );
        }
        "trace" => {
            let Some(name) = args.get(1).filter(|a| !a.starts_with("--")) else {
                eprintln!("usage: ltrf trace <workload> [--cycles N]");
                std::process::exit(2);
            };
            let Some(spec) = suite::workload_by_name(name) else {
                eprintln!("unknown workload `{name}`");
                std::process::exit(1);
            };
            let hierarchy = opt("--hierarchy")
                .as_deref()
                .and_then(designs::by_name)
                .map(|p| p.hierarchy)
                .unwrap_or(ltrf::sim::HierarchyKind::Ltrf { plus: true });
            let factor: f64 = opt("--latency").and_then(|s| s.parse().ok()).unwrap_or(6.3);
            let max: u64 = opt("--cycles").and_then(|s| s.parse().ok()).unwrap_or(200);
            let cfg = ltrf::sim::SimConfig::with_hierarchy(hierarchy)
                .with_latency_factor(factor)
                .normalize_capacity();
            let kernel = ltrf::workloads::gen::build(spec);
            let ck = ltrf::compiler::compile(
                &kernel,
                ltrf::sim::gpu::compile_options(&cfg, true),
            );
            let resident = cfg.resident_warps(ck.kernel.num_regs);
            let mut shared = ltrf::sim::memsys::SharedMem::new(cfg.mem);
            let mut sm = ltrf::sim::sm::SmSim::new(&cfg, &ck, resident, 0);
            println!(
                "trace: {name} on {} @{factor}x, {resident} resident warps (A=active P=prefetch M=mem W=wait .=not started F=finished)",
                hierarchy.name()
            );
            let mut now = 0u64;
            while now < max && !sm.done() {
                let hint = sm.step(now, &mut ltrf::sim::sm::MemPort::Inline(&mut shared));
                let line: String = (0..resident.min(32))
                    .map(|w| match sm.warp_state(w) {
                        ltrf::sim::warp::WarpState::Active => 'A',
                        ltrf::sim::warp::WarpState::Prefetching { .. } => 'P',
                        ltrf::sim::warp::WarpState::Refetching { .. } => 'p',
                        ltrf::sim::warp::WarpState::PendingMem { .. } => 'M',
                        ltrf::sim::warp::WarpState::WaitActivate => 'W',
                        ltrf::sim::warp::WarpState::NotStarted => '.',
                        ltrf::sim::warp::WarpState::Finished => 'F',
                    })
                    .collect();
                println!(
                    "{now:>6} [{line}] issued={} prefetches={}",
                    sm.stats.instructions, sm.stats.prefetch_ops
                );
                now = hint.max(now + 1);
            }
            println!(
                "\n{} instructions in {now} cycles (IPC {:.3})",
                sm.stats.instructions,
                sm.stats.instructions as f64 / now.max(1) as f64
            );
        }
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command `{other}`\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}
