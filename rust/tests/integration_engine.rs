//! Integration: the declarative parallel experiment engine (ticket API).
//!
//! The paper-regeneration contract: a figure's numbers may not depend on
//! how the job matrix is executed. `--jobs 1` and `--jobs 8` must produce
//! bit-identical `Stats` for every point, shared points must be simulated
//! once, and each unique `(workload, CompileOptions)` pair must be
//! compiled exactly once per run (with cache hits for every share).

use ltrf::coordinator::engine::{CfgTweaks, Engine, JobTicket};
use ltrf::coordinator::experiments::{self as exp, DesignUnderTest, ExperimentContext};
use ltrf::sim::{HierarchyKind, Stats};
use ltrf::workloads::{suite, WorkloadSpec};

/// 3 workloads × 3 designs (the §6 comparison minus RFC) + per-workload
/// baseline — the canonical small matrix.
fn matrix() -> (Vec<&'static WorkloadSpec>, Vec<DesignUnderTest>, f64) {
    let workloads: Vec<_> = ["kmeans", "gaussian", "pathfinder"]
        .iter()
        .map(|n| suite::workload_by_name(n).unwrap())
        .collect();
    let designs = vec![
        DesignUnderTest::new(HierarchyKind::Baseline, false),
        DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, false),
        DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, true),
    ];
    (workloads, designs, 4.0)
}

fn run_matrix(threads: usize) -> (Vec<Stats>, u64, u64, u64) {
    let (workloads, designs, factor) = matrix();
    let mut eng = Engine::new(threads);
    let mut tickets: Vec<JobTicket> = Vec::new();
    for &spec in &workloads {
        for d in &designs {
            tickets.push(eng.request(spec, d, factor));
        }
    }
    eng.execute();
    let out: Vec<Stats> = tickets.iter().map(|t| eng.redeem(t)).collect();
    (out, eng.sims_run(), eng.compile_cache().hits(), eng.compile_cache().misses())
}

#[test]
fn jobs1_vs_jobs8_bit_identical() {
    let (serial, s_sims, _, _) = run_matrix(1);
    let (parallel, p_sims, _, _) = run_matrix(8);
    assert_eq!(serial.len(), 9);
    assert_eq!(s_sims, 9);
    assert_eq!(p_sims, 9);
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a, b, "point {i}: stats must be bit-identical across --jobs");
    }
    // Sanity: the matrix did real work.
    assert!(serial.iter().all(|s| s.instructions > 0 && s.cycles > 0));
}

#[test]
fn compile_cache_hits_for_every_shared_design_point() {
    let (_, _, hits, misses) = run_matrix(8);
    // Per workload: BL and LTRF share compile options (both interval
    // mode, no renumber — the hierarchy only affects the simulator), and
    // LTRF_conf compiles its own renumbered kernel. So 2 unique pairs per
    // workload and at least one hit per shared design point.
    assert_eq!(misses, 6, "each unique (workload, options) pair compiles exactly once");
    assert_eq!(hits, 3, "the shared design point must hit the compile cache");
}

#[test]
fn analysis_cache_shares_across_design_points() {
    // The pass-manager layer below the whole-compile cache: LTRF and
    // LTRF_conf are *distinct* (workload, options) pairs, yet they share
    // interval formation + merge through the engine's shared analysis
    // cache. This is the cross-design-point saving whole-compile
    // memoization could never express.
    let (workloads, designs, factor) = matrix();
    let mut eng = Engine::new(2);
    for &spec in &workloads {
        for d in &designs {
            eng.request(spec, d, factor);
        }
    }
    eng.execute();
    let report = eng.compile_cache().report();
    assert_eq!(report.compile_misses, 6);
    assert!(
        report.analysis_hits > 0,
        "cross-design-point sweeps must share analyses: {report:?}"
    );
    assert!(report.analysis_misses > 0, "some passes are genuinely computed: {report:?}");
    // Exactly one subgraph chain shared per workload here (plain ↔ conf
    // share interval-form + merge-reduce), so at least 2 hits each.
    assert!(report.analysis_hits >= 2 * workloads.len() as u64, "{report:?}");
    // The ResultSet carries the same report for drivers/CLI to render.
    assert_eq!(eng.results().cache, report);
    assert!(eng.results().cache.analysis_hit_rate() > 0.0);
}

#[test]
fn figure_tables_byte_identical_across_jobs() {
    // End-to-end through a real figure driver: fig14 exercises shared
    // baselines, multiple designs, and two panels. Ticket-API drivers
    // declare + execute + render internally.
    let render = |threads: usize| -> String {
        let ctx = ExperimentContext { jobs: threads, ..ExperimentContext::quick() };
        let mut eng = Engine::new(threads);
        let tables = exp::fig14(&ctx, &mut eng);
        tables.iter().map(|t| t.render()).collect::<Vec<_>>().join("\n")
    };
    let one = render(1);
    let eight = render(8);
    assert_eq!(one, eight, "--jobs 1 and --jobs 8 must render byte-identical tables");
    assert!(one.contains("GMEAN"));
}

#[test]
fn tweaked_jobs_are_distinct_points() {
    let spec = suite::workload_by_name("kmeans").unwrap();
    let dut = DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, false);
    let mut eng = Engine::new(2);
    let t_on = eng.request_tweaked(spec, &dut, 4.0, CfgTweaks::NONE);
    let t_off = eng.request_tweaked(
        spec,
        &dut,
        4.0,
        CfgTweaks { early_refetch: Some(false), ..CfgTweaks::NONE },
    );
    eng.execute();
    assert_eq!(eng.sims_run(), 2);
    let on = eng.redeem(&t_on);
    let off = eng.redeem(&t_off);
    // §3.2: overlapping the refetch with execution must not hurt.
    assert!(on.ipc() >= off.ipc() * 0.95, "early refetch regressed: {} vs {}", on.ipc(), off.ipc());
    assert!(on.instructions > 0 && off.instructions > 0);
}

#[test]
fn undeclared_point_falls_back_and_matches_declared_run() {
    // A point never declared before execute (the adaptive tolerable-
    // latency scans hit this path) must come out identical to a declared
    // one: `point` falls back to an on-demand simulation through the
    // same caches.
    let spec = suite::workload_by_name("gaussian").unwrap();
    let dut = DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, false);
    let declared = {
        let mut eng = Engine::new(2);
        let t = eng.request(spec, &dut, 6.3);
        eng.execute();
        eng.redeem(&t)
    };
    let fallback = {
        let mut eng = Engine::new(2);
        eng.execute(); // empty matrix
        eng.point(spec, &dut, 6.3) // on-demand
    };
    assert_eq!(declared, fallback);
}
