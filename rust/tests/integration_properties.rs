//! Cross-module property tests over randomly generated kernels.

use ltrf::compiler::{compile, CompileOptions};
use ltrf::ir::{analysis, execute, parser};
use ltrf::sim::{gpu, HierarchyKind, SimConfig};
use ltrf::util::prop;
use ltrf::workloads::gen;

/// display → parse → display is a fixpoint, and parsing preserves
/// semantics, for arbitrary generated kernels.
#[test]
fn prop_parser_roundtrip_random_kernels() {
    prop::check(48, 0x70AD, |rng| {
        let k = gen::random_kernel(rng, 24);
        let text = k.display();
        let k2 = parser::parse(&text).expect("reparse of displayed kernel");
        assert_eq!(text, k2.display(), "display must be a fixpoint");
        let a = execute(&k, 5, &[], 500_000, false);
        let b = execute(&k2, 5, &[], 500_000, false);
        assert_eq!(a.stores, b.stores);
        assert_eq!(a.dyn_insts, b.dyn_insts);
    });
}

/// The full compile pipeline never changes observable behaviour, for any
/// mode/N/renumber combination.
#[test]
fn prop_compile_semantics_invariant() {
    prop::check(32, 0xC0DE, |rng| {
        let k = gen::random_kernel(rng, 24);
        let baseline = execute(&k, 11, &[], 500_000, false);
        for (n, renumber) in [(8usize, false), (16, true), (32, true)] {
            let mut opts = CompileOptions::ltrf(n);
            opts.renumber = renumber;
            let ck = compile(&k, opts);
            let out = execute(
                &ck.kernel,
                11,
                &[(ck.map_reg(0), 0)],
                500_000,
                false,
            );
            assert_eq!(baseline.stores, out.stores, "N={n} renumber={renumber}");
            assert_eq!(baseline.dyn_insts, out.dyn_insts);
        }
    });
}

/// Dominator facts hold on random kernels: the entry dominates all blocks
/// and every idom actually dominates its block.
#[test]
fn prop_dominators_sound() {
    prop::check(48, 0xD0A, |rng| {
        let k = gen::random_kernel(rng, 20);
        let dom = analysis::Dominators::compute(&k);
        for b in 0..k.num_blocks() {
            assert!(dom.dominates(0, b));
            assert!(dom.dominates(dom.idom[b], b));
        }
    });
}

/// Skip-ahead hints are sound: stepping an SM at any cycle strictly
/// before its returned hint must change nothing except the
/// `stall_no_ready_warp` diagnostic. Proven end-to-end by running the
/// same SM densely (stepped at every cycle, so it visits every cycle
/// the hint said to skip) and sparsely (hint-following), on both the
/// inline and deferred memory ports, and comparing final stats with
/// the stall diagnostic zeroed. This is the invariant that licenses the
/// event wheel's `next_event_hint` and the `issue_min` lower-bound
/// cache — an over-estimated hint would show up here as diverging
/// instruction/memory counters. It also exercises the wheel's rollover
/// partition-invariance: the dense run polls the wheel at every cycle,
/// the sparse run only at hints, yet `event_wheel_rollovers` must
/// match.
#[test]
fn prop_skip_ahead_hints_are_sound() {
    use ltrf::sim::memsys::SharedMem;
    use ltrf::sim::sm::{MemPort, SmSim};
    prop::check(10, 0x41A7, |rng| {
        let kind = *rng.choose(&[
            HierarchyKind::Baseline,
            HierarchyKind::Rfc,
            HierarchyKind::Ltrf { plus: false },
            HierarchyKind::Ltrf { plus: true },
        ]);
        let factor = *rng.choose(&[1.0f64, 4.0]);
        // Replay off: this property compares dense (every-cycle) against
        // sparse (hint-following) polling, and the replay engine's
        // recording cadence is defined over driver polls — the seven
        // diagnostics would legitimately differ between the two. Replay
        // soundness has its own oracle (replay-equivalence).
        let cfg = SimConfig {
            replay: false,
            ..SimConfig::with_hierarchy(kind).with_latency_factor(factor).normalize_capacity()
        };
        let kernel = gen::random_kernel(rng, 24);
        let ck = compile(&kernel, gpu::compile_options(&cfg, false));
        let resident = cfg.resident_warps(ck.kernel.num_regs);
        for deferred in [false, true] {
            let run = |dense: bool| {
                let mut shared = SharedMem::new(cfg.mem);
                let mut sm = SmSim::new(&cfg, &ck, resident, 0);
                let mut now = 0u64;
                while !sm.done() {
                    let hint = if deferred {
                        let h = sm.step(now, &mut MemPort::Deferred, u64::MAX);
                        sm.commit_mem(&mut shared);
                        h
                    } else {
                        sm.step(now, &mut MemPort::Inline(&mut shared), u64::MAX)
                    };
                    assert!(now < 10_000_000, "runaway simulation");
                    now = if dense { now + 1 } else { hint.max(now + 1) };
                }
                let mut st = sm.stats.clone();
                st.stall_no_ready_warp = 0;
                (st, shared.llc_hits, shared.llc_misses)
            };
            let dense = run(true);
            let sparse = run(false);
            assert_eq!(dense, sparse, "kind={} factor={factor} deferred={deferred}", kind.name());
        }
    });
}

/// Simulation conservation laws: every resident warp finishes exactly
/// once, instruction counts match the architectural stream, and cache
/// residency is bounded by the partition size throughout.
#[test]
fn prop_simulation_conservation() {
    prop::check(12, 0x51AB, |rng| {
        let spec = *rng.choose(&ltrf::workloads::suite::suite().as_slice());
        let kind = *rng.choose(&[
            HierarchyKind::Baseline,
            HierarchyKind::Rfc,
            HierarchyKind::Ltrf { plus: true },
            HierarchyKind::Carf,
        ]);
        let factor = *rng.choose(&[1.0f64, 3.0, 6.3]);
        let cfg = SimConfig::with_hierarchy(kind).with_latency_factor(factor).normalize_capacity();
        let kernel = gen::build(spec);
        let ck = compile(&kernel, gpu::compile_options(&cfg, false));
        let resident = cfg.resident_warps(ck.kernel.num_regs);
        let st = gpu::run(&ck, &cfg);
        assert_eq!(st.warps_finished as usize, resident, "{} on {}", spec.name, kind.name());
        // Per-warp architectural instruction count matches the sim count.
        let mut expect = 0u64;
        for w in 0..resident {
            let out = execute(
                &ck.kernel,
                ltrf::sim::sm::warp_salt(0, w),
                &[(ck.map_reg(0), ltrf::sim::sm::warp_base(w))],
                10_000_000,
                false,
            );
            expect += out.dyn_insts;
        }
        assert_eq!(st.instructions, expect, "{} on {}", spec.name, kind.name());
    });
}
