//! Integration: the three-layer bridge. Compiled kernels' real prefetch
//! bit-vectors flow through the PJRT artifact (L1 Pallas kernel inside the
//! L2 JAX model) and must agree exactly with both the rust reference
//! evaluator and the compiler's own conflict accounting.

use ltrf::compiler::{compile, renumber, CompileOptions};
use ltrf::runtime::prefetch_eval::{evaluate_reference, LatencyParams};
use ltrf::runtime::PrefetchEvaluator;
use ltrf::util::bitset::MAX_REGS;
use ltrf::workloads::{gen, suite};
use std::path::Path;

fn artifact_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn interleave_assign() -> [usize; MAX_REGS] {
    let mut a = [0usize; MAX_REGS];
    for (r, slot) in a.iter_mut().enumerate() {
        *slot = r % 16;
    }
    a
}

#[test]
fn artifact_agrees_on_real_compiled_working_sets() {
    let ev = match PrefetchEvaluator::load(&artifact_dir()) {
        Ok(ev) => ev,
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e:#}");
            return;
        }
    };
    assert!(ev.is_pjrt());
    let params = LatencyParams::default();
    let assign = interleave_assign();
    for spec in suite::suite() {
        let kernel = gen::build(spec);
        let ck = compile(&kernel, CompileOptions::ltrf(16));
        let sets: Vec<_> = ck.intervals.intervals.iter().map(|i| i.working_set).collect();
        let got = ev.evaluate(&sets, &assign, params).unwrap();
        let want = evaluate_reference(&sets, &assign, params);
        assert_eq!(got, want, "{}: PJRT vs reference mismatch", spec.name);
        // Cross-check against the compiler's own conflict metric.
        for (ws, row) in sets.iter().zip(&got) {
            assert_eq!(
                row.conflicts as usize,
                renumber::bank_conflicts(ws, 16, ltrf::compiler::BankMap::Interleave),
                "{}",
                spec.name
            );
            assert_eq!(row.total as usize, ws.len());
        }
    }
}

#[test]
fn artifact_latency_model_matches_simulator_inputs() {
    let ev = PrefetchEvaluator::load_or_reference(&artifact_dir());
    // A conflict-free 8-register set at 13-cycle banks, 2 regs/cycle xbar,
    // 4-cycle traversal: 13 + 4 + 4 = 21 cycles.
    let ws = ltrf::util::RegSet::from_iter(0u16..8);
    let rows = ev
        .evaluate(
            &[ws],
            &interleave_assign(),
            LatencyParams { mrf_cycles: 13.0, xbar_rate: 2.0, xbar_latency: 4.0 },
        )
        .unwrap();
    assert_eq!(rows[0].conflicts, 0);
    assert_eq!(rows[0].latency, 21);
}
