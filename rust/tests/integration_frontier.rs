//! Integration: the Pareto-frontier auto-tuner (`coordinator::frontier`).
//!
//! Pins the acceptance criteria of the frontier driver: the emitted
//! Pareto set is byte-identical across `--jobs 1` vs `--jobs 8` and
//! across cold vs warm memo store (a warm re-search simulates nothing,
//! on-demand scan tails included); every scored candidate sources its
//! design from the registry; and the sweep-service front end emits
//! request files a `sweep serve` pass accepts verbatim.

use ltrf::coordinator::engine::Engine;
use ltrf::coordinator::frontier::{self, FrontierSpace};
use ltrf::coordinator::{designs, service, MemoStore};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

fn tmpdir(tag: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let d = std::env::temp_dir().join(format!(
        "ltrf-it-frontier-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::SeqCst)
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A small space that still spans two capacities (so the capacity axis
/// of the dominance prune is live) without the full quick workload set.
fn small_space() -> FrontierSpace {
    let mut space = FrontierSpace::new(true);
    space.workloads.truncate(2); // kmeans, gaussian
    space.capacities = vec![2048, 4096];
    space
}

fn render(report: &frontier::FrontierReport) -> String {
    let mut out: String = report.tables().iter().map(|t| t.render()).collect();
    out.push_str(&report.summary());
    out
}

#[test]
fn frontier_is_byte_identical_across_jobs() {
    let space = small_space();
    let run = |jobs: usize| {
        let mut eng = Engine::new(jobs);
        render(&frontier::search(&mut eng, &space))
    };
    let one = run(1);
    let eight = run(8);
    assert_eq!(one, eight, "--jobs must not change the frontier output");
    assert!(one.contains("Pareto frontier"));
}

#[test]
fn warm_search_simulates_nothing_and_reproduces_the_frontier() {
    let dir = tmpdir("warm");
    let space = small_space();
    let run = |jobs: usize| {
        let mut eng = Engine::new(jobs);
        eng.set_store(MemoStore::open(&dir));
        let report = frontier::search(&mut eng, &space);
        eng.flush_store().unwrap();
        (render(&report), eng)
    };
    let (cold_text, cold_eng) = run(1);
    assert!(cold_eng.sims_run() > 0, "cold search simulates its scans");

    // Warm pass at a different job count: cold vs warm AND jobs
    // determinism in one comparison, exactly like the CI smoke.
    let (warm_text, warm_eng) = run(8);
    assert_eq!(
        warm_eng.sims_run(),
        0,
        "a warm re-search must answer every point (scan tails included) from disk"
    );
    assert!(warm_eng.store().unwrap().hits() > 0);
    assert_eq!(warm_eng.store().unwrap().misses(), 0);
    assert_eq!(cold_text, warm_text, "cold and warm frontiers must be byte-identical");
}

#[test]
fn every_candidate_sources_the_registry_and_scores_are_sane() {
    let space = small_space();
    let mut eng = Engine::new(4);
    let report = frontier::search(&mut eng, &space);

    assert_eq!(report.points.len(), designs::REGISTRY.len() * space.capacities.len());
    let frontier_pts = report.frontier();
    assert!(!frontier_pts.is_empty(), "some candidate must survive the prune");
    for p in &report.points {
        assert_eq!(designs::REGISTRY[p.registry_index].name, p.design, "registry-sourced");
        assert!(space.capacities.contains(&p.capacity));
        assert!(p.tolerable_factor >= 1.0);
        assert!(p.ipc > 0.0 && p.power > 0.0);
    }
    // Dominance sanity: no frontier point may dominate another frontier
    // point on all three axes strictly.
    for a in &frontier_pts {
        for b in &frontier_pts {
            assert!(
                !(a.ipc > b.ipc && a.power < b.power && a.capacity > b.capacity),
                "{}-c{} strictly dominates {}-c{} yet both are on the frontier",
                a.design,
                a.capacity,
                b.design,
                b.capacity
            );
        }
    }
    // The report's workload names come from the space.
    assert_eq!(report.workloads.len(), space.workloads.len());
}

#[test]
fn emitted_requests_spool_through_the_sweep_service() {
    let spool = tmpdir("spool");
    let reqdir = tmpdir("requests");
    let space = small_space();
    let files = frontier::emit_requests(&space, &reqdir).unwrap();
    assert_eq!(files.len(), designs::REGISTRY.len() * space.capacities.len());

    // Every emitted file passes `sweep submit` validation and expands to
    // a non-empty point set under its own name.
    for f in &files {
        let msg = service::submit(&spool, f).unwrap();
        let stem = f.file_stem().unwrap().to_str().unwrap();
        assert!(msg.contains(&format!("submitted {stem}:")), "{msg}");
        let spooled = spool.join(format!("{stem}.json"));
        let text = std::fs::read_to_string(&spooled).unwrap();
        let req = service::parse_request(&text, stem).unwrap();
        assert_eq!(req.name, stem);
        assert!(!req.points.is_empty());
    }
}
