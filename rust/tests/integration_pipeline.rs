//! Integration: compiler → simulator across the whole suite and all
//! hierarchies; checks the cross-module invariants DESIGN.md §4 lists.

use ltrf::compiler::pipeline::compile_legacy;
use ltrf::compiler::{compile, CompileOptions, PassManager, SubgraphMode};
use ltrf::ir::execute;
use ltrf::sim::{gpu, HierarchyKind, SimConfig};
use ltrf::workloads::{gen, suite};

#[test]
fn full_suite_compiles_with_valid_intervals() {
    for spec in suite::suite() {
        let kernel = gen::build(spec);
        for n in [8usize, 16, 32] {
            let ck = compile(&kernel, CompileOptions::ltrf(n));
            assert_eq!(ck.intervals.validate(&ck.kernel), Ok(()), "{} N={n}", spec.name);
            for iv in &ck.intervals.intervals {
                assert!(iv.working_set.len() <= n, "{} N={n}", spec.name);
            }
        }
    }
}

/// The pass manager (through which `compile` now routes) is bit-identical
/// to the legacy single-shot pipeline across the whole benchmark suite
/// and every compile variant, with a shared (warm) analysis cache.
#[test]
fn pass_manager_matches_legacy_across_the_suite() {
    let mgr = PassManager::new();
    for spec in suite::suite() {
        let kernel = gen::build(spec);
        for opts in [
            CompileOptions::ltrf(8),
            CompileOptions::ltrf_conf(16),
            CompileOptions::strands(16),
        ] {
            let legacy = compile_legacy(&kernel, opts);
            let cold = mgr.compile(&kernel, opts).expect("valid options");
            assert_eq!(cold, legacy, "{} {opts:?}: cold", spec.name);
            let warm = mgr.compile(&kernel, opts).expect("valid options");
            assert_eq!(warm, legacy, "{} {opts:?}: warm", spec.name);
        }
    }
    assert!(mgr.hits() > 0, "warm recompiles must be served from the cache");
}

/// Traced compiles expose the cold→warm transition and a stable output
/// fingerprint.
#[test]
fn compile_trace_reports_cold_then_warm() {
    let spec = suite::workload_by_name("kmeans").unwrap();
    let kernel = gen::build(spec);
    let mgr = PassManager::new();
    let (ck, cold) = mgr.compile_traced(&kernel, CompileOptions::ltrf_conf(16)).unwrap();
    assert!(cold.passes.iter().all(|p| !p.cached));
    assert_eq!(cold.passes.len(), 7, "interval-form, merge, icg, coloring, renumber, live, dead");
    assert_eq!(cold.output, ck.kernel.fingerprint());
    let (ck2, warm) = mgr.compile_traced(&kernel, CompileOptions::ltrf_conf(16)).unwrap();
    assert_eq!(warm.cache_hits(), warm.passes.len(), "fully warm");
    assert_eq!(ck2, ck);
}

#[test]
fn renumbering_preserves_suite_semantics() {
    for spec in suite::suite() {
        let kernel = gen::build(spec);
        let plain = compile(&kernel, CompileOptions::ltrf(16));
        let conf = compile(&kernel, CompileOptions::ltrf_conf(16));
        let a = execute(
            &plain.kernel,
            7,
            &[(plain.map_reg(gen::REG_BASE), 0x1_0000u32)],
            3_000_000,
            false,
        );
        let b = execute(
            &conf.kernel,
            7,
            &[(conf.map_reg(gen::REG_BASE), 0x1_0000u32)],
            3_000_000,
            false,
        );
        assert!(a.finished && b.finished, "{}", spec.name);
        assert_eq!(a.stores, b.stores, "{}: stores differ after renumbering", spec.name);
        assert_eq!(a.dyn_insts, b.dyn_insts, "{}", spec.name);
    }
}

#[test]
fn renumbering_never_increases_suite_conflicts() {
    for spec in suite::suite() {
        let kernel = gen::build(spec);
        let plain = compile(&kernel, CompileOptions::ltrf(16));
        let conf = compile(&kernel, CompileOptions::ltrf_conf(16));
        assert!(
            conf.conflict_free_fraction() >= plain.conflict_free_fraction(),
            "{}: conflict-free {:.2} -> {:.2}",
            spec.name,
            plain.conflict_free_fraction(),
            conf.conflict_free_fraction()
        );
    }
}

#[test]
fn every_hierarchy_completes_every_quick_workload() {
    for name in ["kmeans", "bfs", "cfd"] {
        let spec = suite::workload_by_name(name).unwrap();
        for kind in HierarchyKind::ALL {
            let cfg = SimConfig::with_hierarchy(kind).with_latency_factor(6.3).normalize_capacity();
            let st = gpu::run_workload(spec, &cfg, kind.uses_subgraphs());
            assert!(st.warps_finished > 0, "{name} on {}", kind.name());
            assert!(st.cycles < cfg.max_cycles, "{name} on {} hit cycle cap", kind.name());
            assert!(st.ipc() > 0.01, "{name} on {}: ipc {}", kind.name(), st.ipc());
        }
    }
}

#[test]
fn ltrf_service_guarantee_holds_under_strands_too() {
    // The debug_assert inside read_operands fires if any in-interval access
    // misses the RF$; running LTRF in both subgraph modes exercises it.
    let spec = suite::workload_by_name("gaussian").unwrap();
    for mode in [SubgraphMode::RegisterIntervals, SubgraphMode::Strands] {
        let cfg = SimConfig::with_hierarchy(HierarchyKind::Ltrf { plus: true })
            .with_latency_factor(4.0);
        let kernel = gen::build(spec);
        let mut opts = gpu::compile_options(&cfg, false);
        opts.mode = mode;
        let ck = compile(&kernel, opts);
        let st = gpu::run(&ck, &cfg);
        assert!(st.warps_finished > 0, "{mode:?}");
        // All operand reads served by the cache.
        assert_eq!(st.mrf_reads, st.prefetch_regs, "{mode:?}: only prefetches touch the MRF");
    }
}

#[test]
fn capacity_scales_resident_warps_and_work() {
    let spec = suite::workload_by_name("cfd").unwrap(); // 188 regs/thread
    let small = SimConfig::with_hierarchy(HierarchyKind::Baseline);
    let big = SimConfig { warp_regs_capacity: 16384, ..small };
    let s = gpu::run_workload(spec, &small, false);
    let b = gpu::run_workload(spec, &big, false);
    // 2048/188 = 10 warps vs 64 warps: 6.4× the instructions.
    assert_eq!(small.resident_warps(188), 10);
    assert_eq!(big.resident_warps(188), 64);
    assert!(b.instructions > 6 * s.instructions);
}
