//! End-to-end tests for the differential scenario engine: round-trip
//! properties over the benchmark suite and the fuzzer corpus, a real
//! (small) fuzz run, the shrinker, the golden-stats snapshot, and the
//! "deliberately broken pass" acceptance checks.

use ltrf::compiler::{compile, CompileOptions};
use ltrf::ir::parser;
use ltrf::scenario::{generator, oracles, shrink, snapshot, FuzzOptions};
use ltrf::workloads::{gen, suite};
use std::path::PathBuf;

// ---------------------------------------------------------------------
// Round-trip properties (pretty-printer <-> parser)
// ---------------------------------------------------------------------

/// `parse(print(k)) == k` (modulo label names) for all 14 benchmarks.
#[test]
fn suite_kernels_roundtrip_through_parser() {
    for spec in suite::suite() {
        let k = gen::build(spec);
        let text = k.display();
        let k2 = parser::parse(&text)
            .unwrap_or_else(|e| panic!("{}: reparse failed: {e:#}", spec.name));
        assert_eq!(text, k2.display(), "{}: display not a fixpoint", spec.name);
        assert!(k.structurally_eq(&k2), "{}: structural mismatch", spec.name);
    }
}

/// The same round-trip over 200 fuzzer seeds (covers every shape 25x).
#[test]
fn fuzzer_seeds_roundtrip_through_parser() {
    for seed in 0..200u64 {
        let (shape, k) = generator::generate(seed);
        let text = k.display();
        let k2 = parser::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed} ({}): {e:#}", shape.name()));
        assert_eq!(text, k2.display(), "seed {seed} ({})", shape.name());
        assert!(k.structurally_eq(&k2), "seed {seed} ({})", shape.name());
    }
}

// ---------------------------------------------------------------------
// Fuzz pipeline
// ---------------------------------------------------------------------

/// A small end-to-end fuzz run over every shape must come back green.
#[test]
fn fuzz_run_is_green_over_all_shapes() {
    let opts = FuzzOptions {
        seed_start: 0,
        seed_end: 16,
        jobs: 0,
        corpus_dir: PathBuf::from("/nonexistent/ltrf-it-corpus"),
        write_repros: false,
        ..Default::default()
    };
    let report = ltrf::scenario::run_fuzz(&opts);
    assert!(report.ok(), "oracle failures: {:#?}", report.failures);
    assert_eq!(report.seeds_run, 16);
    // Every shape appears twice in 16 rotating seeds.
    for (name, count) in &report.shape_counts {
        assert_eq!(*count, 2, "shape {name}");
    }
    assert!(report.sims >= 16 * 10, "matrix sims ran ({})", report.sims);
    assert!(report.checks == 16 * 8, "all oracles checked ({})", report.checks);
}

/// The committed corpus seeds replay cleanly (parse + oracles).
#[test]
fn committed_corpus_seeds_replay_green() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("corpus");
    let opts = FuzzOptions {
        seed_start: 0,
        seed_end: 1, // one generated seed; the corpus is the point
        jobs: 1,
        corpus_dir: root,
        write_repros: false,
        ..Default::default()
    };
    let report = ltrf::scenario::run_fuzz(&opts);
    assert!(report.corpus_replayed >= 3, "committed seeds found");
    assert!(report.ok(), "corpus failures: {:#?}", report.failures);
}

/// Shrinking a sim-level failure predicate produces a minimal repro that
/// still parses and still fails.
#[test]
fn shrinker_produces_minimal_failing_repro() {
    // Use a barrier/SFU kernel and an artificial "contains sfu" defect.
    let (_, k) = generator::generate(6); // seed 6 -> barrier-sfu-mix
    let text = k.display();
    fn contains_sfu(k: &ltrf::ir::Kernel) -> bool {
        k.blocks.iter().any(|b| b.insts.iter().any(|i| i.op == ltrf::ir::Op::Sfu))
    }
    if !contains_sfu(&k) {
        // Shape mixes ops randomly; fall back to another seed if needed.
        return;
    }
    let r = shrink::shrink(&text, 400, &mut contains_sfu);
    let k2 = parser::parse(&r.text).expect("minimized repro parses");
    assert!(contains_sfu(&k2), "minimized repro lost the defect");
    assert!(
        r.text.lines().count() < text.lines().count(),
        "shrinker removed nothing:\n{}",
        r.text
    );
}

// ---------------------------------------------------------------------
// Acceptance: deliberately breaking a pass must trip an oracle
// ---------------------------------------------------------------------

/// Flipping one bank assignment in a cleanly-colored kernel must fail the
/// renumbering oracle (the ISSUE's acceptance check, in unit form).
#[test]
fn bank_flip_trips_renumber_oracle() {
    // A tiny straight-line kernel is always cleanly colorable.
    let (_, k) = generator::generate(0); // seed 0 -> one-interval
    let mut ck = compile(&k, CompileOptions::ltrf_conf(16));
    let col = ck.coloring.as_ref().expect("coloring ran");
    let rn = ck.renumbering.as_ref().expect("renumber ran");
    assert_eq!(col.forced, 0, "tiny kernel must color cleanly");
    assert_eq!(rn.fallback, 0);
    assert!(oracles::check_renumber_invariants(&ck).is_ok());

    // Flip one register's bank: move some working-set register onto the
    // bank of another (interleaved map: +16 keeps the same bank as +0).
    let ws = &mut ck.intervals.intervals[0].working_set;
    let regs: Vec<u16> = ws.iter().collect();
    assert!(regs.len() >= 2, "working set too small to collide");
    let a = regs[0];
    let b = regs[1];
    let mut clash = a + 16;
    while ws.contains(clash) {
        clash += 16;
    }
    ws.remove(b);
    ws.insert(clash);
    let err = oracles::check_renumber_invariants(&ck).expect_err("bank flip must be caught");
    assert!(err.contains("bank conflicts"), "{err}");
}

/// Perturbing a stat counter must produce a keyed snapshot diff (the
/// ISSUE's other acceptance check, against an in-memory golden).
#[test]
fn counter_perturbation_trips_snapshot_diff() {
    let golden = snapshot::capture(true, 0);
    assert_eq!(golden.entries.len(), 25);

    // Determinism: a second capture diffs clean.
    let again = snapshot::capture(true, 0);
    assert!(golden.diff_against(&again).is_empty(), "capture must be deterministic");

    // Text round-trip.
    let reparsed = snapshot::Snapshot::parse(&golden.to_text()).expect("parse");
    assert_eq!(golden, reparsed);

    // Perturb one counter the way a simulator regression would.
    let mut drifted = golden.clone();
    let (key, fields) = drifted.entries.iter_mut().next().expect("non-empty");
    let key = key.clone();
    for f in fields.iter_mut() {
        if f.0 == "prefetch_ops" || f.0 == "cycles" {
            f.1 += 1;
        }
    }
    let diffs = golden.diff_against(&drifted);
    assert!(!diffs.is_empty(), "perturbation must be detected");
    assert!(diffs[0].contains(&key), "diff is keyed: {}", diffs[0]);
}

/// Snapshot capture is bit-identical across thread counts (the CI gate's
/// `--jobs 1` vs `--jobs 4` comparison, in-process).
#[test]
fn snapshot_capture_thread_count_invariant() {
    let a = snapshot::capture(true, 1);
    let b = snapshot::capture(true, 4);
    assert_eq!(a.to_text(), b.to_text());
}
