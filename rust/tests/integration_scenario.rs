//! End-to-end tests for the differential scenario engine: round-trip
//! properties over the benchmark suite and the fuzzer corpus, a real
//! (small) fuzz run, the shrinker, the golden-stats snapshot, and the
//! "deliberately broken pass" acceptance checks.

use ltrf::compiler::{compile, CompileOptions};
use ltrf::ir::parser;
use ltrf::scenario::{generator, oracles, shrink, snapshot, FuzzOptions};
use ltrf::workloads::{gen, suite};
use std::path::PathBuf;

// ---------------------------------------------------------------------
// Round-trip properties (pretty-printer <-> parser)
// ---------------------------------------------------------------------

/// `parse(print(k)) == k` (modulo label names) for all 14 benchmarks.
#[test]
fn suite_kernels_roundtrip_through_parser() {
    for spec in suite::suite() {
        let k = gen::build(spec);
        let text = k.display();
        let k2 = parser::parse(&text)
            .unwrap_or_else(|e| panic!("{}: reparse failed: {e:#}", spec.name));
        assert_eq!(text, k2.display(), "{}: display not a fixpoint", spec.name);
        assert!(k.structurally_eq(&k2), "{}: structural mismatch", spec.name);
    }
}

/// The same round-trip over 200 fuzzer seeds (covers every shape 22x).
#[test]
fn fuzzer_seeds_roundtrip_through_parser() {
    for seed in 0..200u64 {
        let (shape, k) = generator::generate(seed);
        let text = k.display();
        let k2 = parser::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed} ({}): {e:#}", shape.name()));
        assert_eq!(text, k2.display(), "seed {seed} ({})", shape.name());
        assert!(k.structurally_eq(&k2), "seed {seed} ({})", shape.name());
    }
}

// ---------------------------------------------------------------------
// Fuzz pipeline
// ---------------------------------------------------------------------

/// A small end-to-end fuzz run over every shape must come back green.
#[test]
fn fuzz_run_is_green_over_all_shapes() {
    let opts = FuzzOptions {
        seed_start: 0,
        seed_end: 18,
        jobs: 0,
        corpus_dir: PathBuf::from("/nonexistent/ltrf-it-corpus"),
        write_repros: false,
        ..Default::default()
    };
    let report = ltrf::scenario::run_fuzz(&opts);
    assert!(report.ok(), "oracle failures: {:#?}", report.failures);
    assert_eq!(report.seeds_run, 18);
    // Every shape appears twice in 18 rotating seeds (9 shapes).
    for (name, count) in &report.shape_counts {
        assert_eq!(*count, 2, "shape {name}");
    }
    assert!(report.sims >= 18 * 10, "matrix sims ran ({})", report.sims);
    assert_eq!(report.checks, 18 * oracles::OracleKind::ALL.len() as u64, "all oracles checked");
}

/// The committed corpus seeds replay cleanly (parse + oracles).
#[test]
fn committed_corpus_seeds_replay_green() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("corpus");
    let opts = FuzzOptions {
        seed_start: 0,
        seed_end: 1, // one generated seed; the corpus is the point
        jobs: 1,
        corpus_dir: root,
        write_repros: false,
        ..Default::default()
    };
    let report = ltrf::scenario::run_fuzz(&opts);
    assert!(report.corpus_replayed >= 3, "committed seeds found");
    assert!(report.ok(), "corpus failures: {:#?}", report.failures);
}

/// Shrinking a sim-level failure predicate produces a minimal repro that
/// still parses and still fails.
#[test]
fn shrinker_produces_minimal_failing_repro() {
    // Use a barrier/SFU kernel and an artificial "contains sfu" defect.
    let (_, k) = generator::generate(6); // seed 6 -> barrier-sfu-mix
    let text = k.display();
    fn contains_sfu(k: &ltrf::ir::Kernel) -> bool {
        k.blocks.iter().any(|b| b.insts.iter().any(|i| i.op == ltrf::ir::Op::Sfu))
    }
    if !contains_sfu(&k) {
        // Shape mixes ops randomly; fall back to another seed if needed.
        return;
    }
    let r = shrink::shrink(&text, 400, &mut contains_sfu);
    let k2 = parser::parse(&r.text).expect("minimized repro parses");
    assert!(contains_sfu(&k2), "minimized repro lost the defect");
    assert!(
        r.text.lines().count() < text.lines().count(),
        "shrinker removed nothing:\n{}",
        r.text
    );
}

// ---------------------------------------------------------------------
// Backend equivalence (the two-phase simulator core's headline invariant)
// ---------------------------------------------------------------------

/// Every committed corpus kernel passes the backend-equivalence oracle:
/// `Parallel` == `Reference` field-for-field across the design × latency
/// matrix (CI additionally runs this over 500 fuzz seeds via `fuzz`).
#[test]
fn backend_equivalence_oracle_green_on_committed_corpus() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("corpus");
    let corpus = ltrf::scenario::corpus::load_replay_corpus(&root);
    assert!(corpus.len() >= 3, "committed corpus seeds found");
    for (path, text) in corpus {
        let k = parser::parse(&text).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let mut cs = oracles::CheckStats::default();
        oracles::run_oracle(&k, oracles::OracleKind::BackendEquivalence, &mut cs)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(cs.sims > 0);
    }
}

/// Every committed corpus kernel passes the pass-equivalence oracle: the
/// incremental pass manager compiles bit-identically to the legacy
/// single-shot pipeline (cold + warm cache) across the design × latency
/// matrix, and kernel mutation invalidates every stale analysis.
#[test]
fn pass_equivalence_oracle_green_on_committed_corpus() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("corpus");
    let corpus = ltrf::scenario::corpus::load_replay_corpus(&root);
    assert!(corpus.len() >= 3, "committed corpus seeds found");
    for (path, text) in corpus {
        let k = parser::parse(&text).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let mut cs = oracles::CheckStats::default();
        oracles::run_oracle(&k, oracles::OracleKind::PassEquivalence, &mut cs)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    }
}

/// Every committed corpus kernel passes the replay-equivalence oracle:
/// a replay-enabled run is bit-identical to a dense (`replay: false`)
/// run field-for-field across the design × latency matrix, masking only
/// the seven replay diagnostics (CI additionally runs this over the fuzz
/// seeds via `fuzz`).
#[test]
fn replay_equivalence_oracle_green_on_committed_corpus() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("corpus");
    let corpus = ltrf::scenario::corpus::load_replay_corpus(&root);
    assert!(corpus.len() >= 3, "committed corpus seeds found");
    for (path, text) in corpus {
        let k = parser::parse(&text).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let mut cs = oracles::CheckStats::default();
        oracles::run_oracle(&k, oracles::OracleKind::ReplayEquivalence, &mut cs)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(cs.sims > 0);
    }
}

/// The replay-equivalence oracle's masked comparison has teeth: a
/// deliberately stale (poisoned-fingerprint) replay cell skews a
/// *masked-visible* counter, so `replay_masked_diff` flags the run
/// against its dense twin. Checked for both a solo-warp cell and a
/// two-warp ensemble cell. This is the integration-level proof that the
/// oracle's masking choice (exactly the seven replay diagnostics,
/// nothing else) cannot hide a real replay soundness bug.
#[test]
fn stale_replay_cell_trips_masked_oracle_comparison() {
    use ltrf::sim::memsys::SharedMem;
    use ltrf::sim::sm::{MemPort, SmSim};
    use ltrf::sim::{HierarchyKind, SimConfig};
    // The deterministic replay trigger: a memory-quiescent loop (suite
    // workloads load inside their loops, so they never enter the replay
    // engine's recorded class).
    let src = "
.kernel a
  mov r0, #0
  mov r1, #7
L1:
  add r2, r0, r1
  add r3, r2, r1
  add r4, r3, r2
  add r0, r0, #1
  setp.lt p0, r0, #400
  @p0 bra L1
  st.global [r0], r4
  exit
";
    let k = parser::parse(src).expect("ALU loop parses");
    let run = |warps: usize, replay: bool, poison: bool| {
        let cfg = SimConfig { replay, ..SimConfig::with_hierarchy(HierarchyKind::Baseline) };
        let ck = compile(&k, CompileOptions::ltrf(16));
        let mut shared = SharedMem::new(cfg.mem);
        let mut sm = SmSim::new(&cfg, &ck, warps, 0);
        if poison {
            sm.poison_replay_cells_for_test();
        }
        let mut now = 0;
        while !sm.done() && now < 1_000_000 {
            let hint = sm.step(now, &mut MemPort::Inline(&mut shared), u64::MAX);
            now = hint.max(now + 1).min(1_000_000);
        }
        let mut st = sm.stats.clone();
        st.cycles = now;
        st
    };
    for warps in [1usize, 2] {
        let dense = run(warps, false, false);
        // Sound replay: masked comparison sees no difference.
        let sound = run(warps, true, false);
        assert!(
            sound.replay_fast_forwards > 0,
            "warps={warps}: replay must fire for the test to mean anything"
        );
        if warps > 1 {
            assert!(
                sound.replay_ensemble_fast_forwards > 0,
                "multi-warp runs must take the ensemble path"
            );
        }
        assert_eq!(
            oracles::replay_masked_diff(&sound, &dense),
            None,
            "warps={warps}: sound replay must be invisible to the masked comparison"
        );
        // Stale cell: the masked comparison must flag it.
        let stale = run(warps, true, true);
        assert!(
            stale.replay_fast_forwards > 0,
            "warps={warps}: poisoned cells must still replay"
        );
        let diff = oracles::replay_masked_diff(&stale, &dense);
        assert!(
            diff.is_some(),
            "warps={warps}: a stale replay cell must trip the masked oracle comparison"
        );
        assert!(
            diff.as_deref().unwrap_or("").contains("instructions"),
            "the poison skews the instruction counter: {diff:?}"
        );
    }
}

/// The golden-snapshot matrix (full workload suite × design × latency in
/// CI; the quick subset here) serializes byte-identically under both
/// backends — the in-process version of the CI `--backend parallel` gate.
#[test]
fn snapshot_backend_capture_byte_identical() {
    use ltrf::coordinator::engine::CfgTweaks;
    use ltrf::sim::SimBackend;
    let reference = snapshot::capture(true, 2);
    let parallel =
        snapshot::capture_tweaked(true, 2, CfgTweaks::with_backend(SimBackend::Parallel, 4));
    assert_eq!(reference.to_text(), parallel.to_text());
}

/// Deliberately violating the canonical `(sm_id, seq)` commit order must
/// change `Stats` on at least one kernel — i.e. the equivalence oracle
/// actually has teeth: an ordering bug in the commit phase cannot hide.
#[test]
fn commit_order_perturbation_trips_backend_equivalence() {
    use ltrf::sim::{gpu, HierarchyKind, SimBackend, SimConfig};
    // Order-stress configuration: two SMs sharing a 1-set/2-way LLC and a
    // single slow DRAM channel, with a tiny L1 so misses reach the shared
    // levels constantly. Under these parameters the interleaving of the
    // two SMs' requests decides LLC victim choice and DRAM queueing.
    let stress_cfg = || {
        let mut cfg = SimConfig::with_hierarchy(HierarchyKind::Ltrf { plus: true });
        cfg.num_sms = 2;
        cfg.warps_per_sm = 16;
        cfg.max_cycles = 8_000_000;
        cfg.mem.l1_lines = 4;
        cfg.mem.l1_assoc = 2;
        cfg.mem.llc_lines = 2;
        cfg.mem.llc_assoc = 2;
        cfg.mem.dram_channels = 1;
        cfg.mem.dram_service_cycles = 64;
        cfg
    };
    let mut trips = 0usize;
    let mut checked = 0usize;
    for seed in 0..16u64 {
        let (_, k) = generator::generate(seed);
        let cfg = stress_cfg();
        let ck = compile(&k, ltrf::sim::gpu::compile_options(&cfg, false));
        let canonical = gpu::run_two_phase(&ck, &cfg, gpu::CommitOrder::Canonical);
        // Sanity: the canonical two-phase core equals the reference
        // backend bit-for-bit even on this adversarial configuration.
        let mut rcfg = cfg;
        rcfg.backend = SimBackend::Reference;
        assert_eq!(
            canonical,
            ltrf::sim::gpu::run(&ck, &rcfg),
            "seed {seed}: canonical two-phase must match reference"
        );
        checked += 1;
        let perturbed = gpu::run_two_phase(&ck, &cfg, gpu::CommitOrder::PerturbedReversed);
        if perturbed != canonical {
            trips += 1;
        }
        if trips > 0 && checked >= 4 {
            break; // proven: the oracle detects ordering bugs
        }
    }
    assert!(
        trips > 0,
        "reversed commit order never changed Stats over {checked} kernels — \
         the backend-equivalence oracle would miss a commit-ordering bug"
    );
}

// ---------------------------------------------------------------------
// Acceptance: deliberately breaking a pass must trip an oracle
// ---------------------------------------------------------------------

/// Flipping one bank assignment in a cleanly-colored kernel must fail the
/// renumbering oracle (the ISSUE's acceptance check, in unit form).
#[test]
fn bank_flip_trips_renumber_oracle() {
    // A tiny straight-line kernel is always cleanly colorable.
    let (_, k) = generator::generate(0); // seed 0 -> one-interval
    let mut ck = compile(&k, CompileOptions::ltrf_conf(16));
    let col = ck.coloring.as_ref().expect("coloring ran");
    let rn = ck.renumbering.as_ref().expect("renumber ran");
    assert_eq!(col.forced, 0, "tiny kernel must color cleanly");
    assert_eq!(rn.fallback, 0);
    assert!(oracles::check_renumber_invariants(&ck).is_ok());

    // Flip one register's bank: move some working-set register onto the
    // bank of another (interleaved map: +16 keeps the same bank as +0).
    let ws = &mut ck.intervals.intervals[0].working_set;
    let regs: Vec<u16> = ws.iter().collect();
    assert!(regs.len() >= 2, "working set too small to collide");
    let a = regs[0];
    let b = regs[1];
    let mut clash = a + 16;
    while ws.contains(clash) {
        clash += 16;
    }
    ws.remove(b);
    ws.insert(clash);
    let err = oracles::check_renumber_invariants(&ck).expect_err("bank flip must be caught");
    assert!(err.contains("bank conflicts"), "{err}");
}

/// Perturbing a stat counter must produce a keyed snapshot diff (the
/// ISSUE's other acceptance check, against an in-memory golden).
#[test]
fn counter_perturbation_trips_snapshot_diff() {
    let golden = snapshot::capture(true, 0);
    assert_eq!(golden.entries.len(), snapshot::snapshot_points(true).len());

    // Determinism: a second capture diffs clean.
    let again = snapshot::capture(true, 0);
    assert!(golden.diff_against(&again).is_empty(), "capture must be deterministic");

    // Text round-trip.
    let reparsed = snapshot::Snapshot::parse(&golden.to_text()).expect("parse");
    assert_eq!(golden, reparsed);

    // Perturb one counter the way a simulator regression would.
    let mut drifted = golden.clone();
    let (key, fields) = drifted.entries.iter_mut().next().expect("non-empty");
    let key = key.clone();
    for f in fields.iter_mut() {
        if f.0 == "prefetch_ops" || f.0 == "cycles" {
            f.1 += 1;
        }
    }
    let diffs = golden.diff_against(&drifted);
    assert!(!diffs.is_empty(), "perturbation must be detected");
    assert!(diffs[0].contains(&key), "diff is keyed: {}", diffs[0]);
}

/// Snapshot capture is bit-identical across thread counts (the CI gate's
/// `--jobs 1` vs `--jobs 4` comparison, in-process).
#[test]
fn snapshot_capture_thread_count_invariant() {
    let a = snapshot::capture(true, 1);
    let b = snapshot::capture(true, 4);
    assert_eq!(a.to_text(), b.to_text());
}
