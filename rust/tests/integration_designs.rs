//! Integration: the design registry is the single source of the policy
//! comparison matrix, and the CARF policy runs end-to-end through every
//! layer that enumerates it (engine → oracles → snapshot → power →
//! bench).

use ltrf::coordinator::designs;
use ltrf::coordinator::engine::{run_point, CfgTweaks, Engine};
use ltrf::scenario::{oracles, snapshot};
use ltrf::sim::{model_for, HierarchyKind};
use ltrf::timing::Tech;
use ltrf::workloads::suite;

/// The acceptance criterion in test form: `oracles`, `snapshot`, and the
/// `engine` all enumerate the one registry (the bench matrix asserts the
/// same in `bench.rs`'s unit tests, where its private point builders are
/// visible).
#[test]
fn oracles_snapshot_and_engine_enumerate_the_registry() {
    // Oracles: one matrix row per registered (design, latency) pair.
    let matrix = oracles::sim_matrix();
    let expected: usize = designs::REGISTRY.iter().map(|p| p.latency_factors.len()).sum();
    assert_eq!(matrix.len(), expected);
    for p in designs::REGISTRY {
        assert!(
            matrix.iter().any(|(n, _, _)| n.split('@').next() == Some(p.name)),
            "{} missing from the oracle matrix",
            p.name
        );
    }

    // Snapshot: every registered design keyed per workload.
    let points = snapshot::snapshot_points(true);
    for p in designs::REGISTRY {
        let tag = format!("|{}|", p.name);
        assert!(
            points.iter().any(|(k, _, _, _)| k.contains(&tag)),
            "{} missing from the snapshot matrix",
            p.name
        );
    }

    // Engine: sweeping the registry closes the coverage gap the
    // `--engine-stats` summary reports (the CI smoke greps the ratio).
    let spec = suite::workload_by_name("kmeans").unwrap();
    let mut eng = Engine::new(2);
    for (_, dut) in designs::all_points(2048) {
        eng.request(spec, &dut, 1.0);
    }
    eng.execute();
    let (covered, registered) = eng.design_coverage();
    assert_eq!(registered, designs::REGISTRY.len());
    assert_eq!(covered, registered, "a registered policy was not swept");
    assert!(eng.summary().contains(&format!("design points {covered}/{registered} registered")));
}

/// CARF end-to-end: the engine point runner simulates it, it converges,
/// it behaves like a cache (hits + misses, no prefetch), and its traffic
/// and power hooks report sane numbers.
#[test]
fn carf_runs_end_to_end_through_the_engine() {
    let spec = suite::workload_by_name("gaussian").unwrap();
    let carf = designs::by_name("carf").expect("CLI spelling resolves");
    assert_eq!(carf.hierarchy, HierarchyKind::Carf);
    let st = run_point(spec, &carf.dut(), 1.0, CfgTweaks::NONE, None);
    assert!(st.warps_finished > 0, "CARF run must complete");
    assert_eq!(st.hit_cycle_cap, 0, "CARF run must converge");
    assert_eq!(st.prefetch_ops, 0, "CARF never prefetches");
    assert!(st.rfc_hits > 0 && st.rfc_misses > 0, "fill-on-demand cache behavior");
    assert!(st.cache_reads > 0 && st.mrf_reads > 0);

    let model = model_for(HierarchyKind::Carf);
    let tr = model.traffic(&st);
    assert_eq!(tr.cache_accesses, st.cache_reads + st.cache_writes);
    assert_eq!(tr.mrf_accesses, st.mrf_reads + st.mrf_writes);

    // Liveness-directed eviction must keep CARF's MRF traffic below the
    // conventional file's (that is the point of the policy).
    let bl = run_point(spec, &designs::baseline().dut(), 1.0, CfgTweaks::NONE, None);
    assert!(
        tr.mrf_accesses < bl.mrf_reads + bl.mrf_writes,
        "CARF must reduce MRF accesses vs BL ({} vs {})",
        tr.mrf_accesses,
        bl.mrf_reads + bl.mrf_writes
    );
}

/// `PowerBreakdown::total` conservation across every registry design
/// point: the components are non-negative, sum to the total, and the
/// idle (zero-stats) breakdown carries the same static/overhead terms as
/// the active one.
#[test]
fn power_breakdown_conserves_across_registry_points() {
    let spec = suite::workload_by_name("kmeans").unwrap();
    for (name, dut) in designs::all_points(2048) {
        let st = run_point(spec, &dut, 1.0, CfgTweaks::NONE, None);
        let model = model_for(dut.hierarchy);
        for (ratio, tech) in [(1.0, Tech::HpSram), (8.0, Tech::Dwm)] {
            let p = model.power(&st, ratio, tech);
            assert!(
                p.dynamic >= 0.0 && p.static_ >= 0.0 && p.overhead >= 0.0,
                "{name}: negative component"
            );
            let sum = p.dynamic + p.static_ + p.overhead;
            assert!((p.total() - sum).abs() < 1e-12, "{name}: total != sum of parts");
            assert!(p.total() > 0.0, "{name}: zero power");
            let idle = model.power(&ltrf::sim::Stats::default(), ratio, tech);
            assert!(
                (idle.static_ + idle.overhead - (p.static_ + p.overhead)).abs() < 1e-12,
                "{name}: idle static power must match the active formula"
            );
        }
    }
}

/// The full oracle suite holds on a CARF-heavy workload path: run every
/// oracle on one committed-corpus-style kernel (the fuzz suite covers
/// hundreds more in CI; this is the fast in-tree witness that the
/// registry extension did not break an invariant).
#[test]
fn oracle_suite_green_with_carf_in_the_matrix() {
    let k = ltrf::workloads::gen::build(suite::workload_by_name("kmeans").unwrap());
    let (cs, failure) = oracles::check_kernel(&k);
    assert!(failure.is_none(), "{failure:?}");
    assert!(
        cs.sims as usize >= oracles::sim_matrix().len(),
        "the conservation oracle alone must cover the whole matrix"
    );
}
