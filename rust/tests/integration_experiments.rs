//! Integration: the paper's qualitative results hold end-to-end (quick
//! context — 5 workloads). These are the shape claims of §7; exact
//! magnitudes are recorded in EXPERIMENTS.md.

use ltrf::coordinator::experiments::{self as exp, DesignUnderTest, ExperimentContext};
use ltrf::coordinator::sweep::gmean;
use ltrf::coordinator::tolerable;
use ltrf::sim::HierarchyKind;
use ltrf::workloads::suite;

fn ctx() -> ExperimentContext {
    ExperimentContext::quick()
}

/// Fig 14's ordering on config #7: BL < RFC ≤ LTRF ≤ LTRF_conf, and
/// LTRF_conf beats the 256KB baseline (the paper's headline direction).
#[test]
fn fig14_ordering_holds_on_config7() {
    let factor = 6.3;
    let cap = 16384;
    let points = exp::comparison_points(cap);
    let mut means = Vec::new();
    for (name, dut) in &points {
        let vals: Vec<f64> = ctx()
            .workloads()
            .iter()
            .map(|spec| dut.run(spec, factor).ipc() / exp::baseline_ipc(spec))
            .collect();
        means.push((*name, gmean(&vals)));
    }
    let get = |n: &str| means.iter().find(|(name, _)| *name == n).unwrap().1;
    let (bl, rfc, ltrf, conf) = (get("BL"), get("RFC"), get("LTRF"), get("LTRF_conf"));
    assert!(bl < rfc, "BL {bl:.2} < RFC {rfc:.2}");
    assert!(rfc < ltrf, "RFC {rfc:.2} < LTRF {ltrf:.2}");
    assert!(conf >= ltrf * 0.98, "LTRF_conf {conf:.2} >= LTRF {ltrf:.2}");
    assert!(conf > 1.0, "LTRF_conf must beat the 256KB baseline ({conf:.2})");
    assert!(bl < 0.6, "BL must collapse at 6.3x latency ({bl:.2})");
}

/// Fig 15's ordering: tolerable latency BL < RFC < LTRF ≤ LTRF_conf.
#[test]
fn fig15_tolerable_latency_ordering() {
    let spec = suite::workload_by_name("gaussian").unwrap();
    let points = exp::comparison_points(2048);
    let t: Vec<f64> = points.iter().map(|(_, d)| tolerable::max_tolerable(d, spec, 0.95)).collect();
    assert!(t[0] < t[2], "BL {} < LTRF {}", t[0], t[2]);
    assert!(t[1] < t[2], "RFC {} < LTRF {}", t[1], t[2]);
    assert!(t[3] >= t[2] * 0.9, "LTRF_conf {} ~>= LTRF {}", t[3], t[2]);
}

/// Fig 4: hardware register cache hit rate is low (the motivation).
#[test]
fn fig4_rfc_hit_rate_low() {
    for name in ["kmeans", "cfd"] {
        let spec = suite::workload_by_name(name).unwrap();
        let st = DesignUnderTest::new(HierarchyKind::Rfc, false).run(spec, 1.0);
        let hr = st.rfc_hit_rate();
        assert!(hr > 0.02 && hr < 0.65, "{name}: RFC hit rate {hr:.2} out of band");
    }
}

/// Fig 19: register-intervals beat strands which beat RFC at high latency.
#[test]
fn fig19_interval_vs_strand_vs_rfc() {
    let factor = 5.0;
    let specs = ctx().workloads();
    let mean_for = |dut: &DesignUnderTest| {
        let vals: Vec<f64> = specs
            .iter()
            .map(|s| dut.run(s, factor).ipc() / exp::baseline_ipc(s))
            .collect();
        gmean(&vals)
    };
    let rfc = mean_for(&DesignUnderTest::new(HierarchyKind::Rfc, false));
    let mut strand = DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, false);
    strand.mode_override = Some(ltrf::compiler::SubgraphMode::Strands);
    let strand = mean_for(&strand);
    let interval = mean_for(&DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, false));
    let bl = mean_for(&DesignUnderTest::new(HierarchyKind::Baseline, false));
    // §7.6's central claim: register-intervals are what make LTRF work —
    // the same prefetch machinery over strands loses a large fraction of
    // the latency tolerance.
    assert!(interval > strand * 1.05, "interval {interval:.2} >> strand {strand:.2}");
    assert!(strand > bl * 1.3, "strand {strand:.2} >> BL {bl:.2}");
    assert!(interval > rfc, "interval {interval:.2} > RFC {rfc:.2}");
}

/// Fig 3(b): raising capacity 8× while taking 5.3× latency erases the
/// gains for the conventional register file.
#[test]
fn fig3_tfet_offsets_capacity_gains() {
    let spec = suite::workload_by_name("cfd").unwrap();
    let base = exp::baseline_ipc(spec);
    let ideal = DesignUnderTest::new(HierarchyKind::Baseline, false)
        .with_capacity(16384)
        .run(spec, 1.0)
        .ipc()
        / base;
    let tfet = DesignUnderTest::new(HierarchyKind::Baseline, false)
        .with_capacity(16384)
        .run(spec, 5.3)
        .ipc()
        / base;
    assert!(ideal > 1.1, "cfd is register-sensitive: ideal {ideal:.2}");
    assert!(tfet < ideal * 0.7, "latency must erase most gains: {tfet:.2} vs {ideal:.2}");
}

/// Table 4: real interval lengths close to optimal, in the paper's band.
#[test]
fn table4_real_close_to_optimal() {
    let mut eng = ltrf::coordinator::Engine::new(0);
    let t = exp::table4(&ctx(), &mut eng);
    let ratio: f64 = t.rows[0][4].trim_end_matches('%').parse().unwrap();
    // Paper: real ≈ 89% of optimal. Our generated loops fit a partition
    // more often than real CUDA (whole loops become one interval, so
    // dynamic runs are long); the control-flow penalty stays small.
    assert!(ratio > 55.0, "real/optimal {ratio}% too low");
    let real_avg: f64 = t.rows[0][1].parse().unwrap();
    assert!(real_avg > 5.0, "mean interval length {real_avg}");
}
