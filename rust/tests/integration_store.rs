//! Integration: the cross-run disk memo store behind the engine.
//!
//! Pins the acceptance criteria for the store: a repeated identical sweep
//! performs zero compiles and zero simulations and leaves the store file
//! byte-identical; a `FINGERPRINT_VERSION` / store-schema / stats-schema
//! bump re-runs the whole matrix; a single knob change re-runs exactly
//! the affected points; a corrupted or truncated store file degrades to
//! cold misses on the damaged entries — never a panic, never wrong stats.

use ltrf::coordinator::designs;
use ltrf::coordinator::engine::{CfgTweaks, Engine};
use ltrf::coordinator::experiments::DesignUnderTest;
use ltrf::coordinator::store::{stats_schema_signature, MemoStore, STORE_SCHEMA_VERSION};
use ltrf::ir::fingerprint::FINGERPRINT_VERSION;
use ltrf::sim::Stats;
use ltrf::workloads::{suite, WorkloadSpec};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

fn tmpdir(tag: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let d = std::env::temp_dir().join(format!(
        "ltrf-it-store-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::SeqCst)
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

type Point = (&'static WorkloadSpec, DesignUnderTest, f64);

/// `workloads × first N registry designs × factors` — the registry order
/// starts BL, RFC, so `n_designs = 2` covers two distinct hierarchies.
fn points(workloads: &[&str], n_designs: usize, factors: &[f64]) -> Vec<Point> {
    let mut out = Vec::new();
    for name in workloads {
        let spec = suite::workload_by_name(name).unwrap();
        for (_, dut) in designs::all_points(2048).into_iter().take(n_designs) {
            for &f in factors {
                out.push((spec, dut, f));
            }
        }
    }
    out
}

/// Declare + execute + redeem `pts` against an engine fronted by `store`,
/// then flush the store to disk.
fn sweep_with(store: MemoStore, pts: &[Point], jobs: usize) -> (Vec<Stats>, Engine) {
    let mut eng = Engine::new(jobs);
    eng.set_store(store);
    let mut tickets = Vec::new();
    for &(spec, dut, f) in pts {
        tickets.push(eng.request_tweaked(spec, &dut, f, CfgTweaks::NONE));
    }
    eng.execute();
    let mut stats = Vec::new();
    for t in &tickets {
        stats.push(eng.redeem(t));
    }
    eng.flush_store().unwrap();
    (stats, eng)
}

#[test]
fn repeated_sweep_is_free_and_byte_identical() {
    let dir = tmpdir("warm");
    let pts = points(&["kmeans", "bfs"], 2, &[1.0, 4.0]);
    let (cold_stats, cold_eng) = sweep_with(MemoStore::open(&dir), &pts, 4);
    assert_eq!(cold_eng.sims_run(), pts.len() as u64);
    assert!(cold_eng.compile_cache().misses() > 0, "cold run really compiled");
    let store_path = dir.join(ltrf::coordinator::store::STORE_FILE);
    let file_cold = std::fs::read(&store_path).unwrap();

    let (warm_stats, warm_eng) = sweep_with(MemoStore::open(&dir), &pts, 4);
    assert_eq!(warm_eng.sims_run(), 0, "repeated identical sweep must simulate nothing");
    assert_eq!(warm_eng.compile_cache().misses(), 0, "...and compile nothing");
    assert_eq!(warm_eng.store().unwrap().hits(), pts.len() as u64);
    assert_eq!(cold_stats, warm_stats, "disk round-trip must not change a single stat");
    let file_warm = std::fs::read(&store_path).unwrap();
    assert_eq!(file_cold, file_warm, "an all-hit sweep must leave the file byte-identical");
}

#[test]
fn version_bumps_re_run_the_whole_matrix() {
    let dir = tmpdir("bumps");
    let pts = points(&["kmeans"], 2, &[1.0]);
    let (_, cold) = sweep_with(MemoStore::open(&dir), &pts, 2);
    assert_eq!(cold.sims_run(), pts.len() as u64);

    let (sv, fpv, sig) = (STORE_SCHEMA_VERSION, FINGERPRINT_VERSION, stats_schema_signature());
    // A store-schema change, a compiler release that moves the kernel
    // fingerprint version, or a Stats counter-set change: each one must
    // discard the file wholesale and re-simulate every point.
    for (s, f, g) in [(sv + 1, fpv, sig), (sv, fpv + 1, sig), (sv, fpv, sig ^ 1)] {
        // Rebuild the on-current-versions store first (the previous bump
        // case left the file under *its* header), so each case starts
        // from a file that is warm for the current versions.
        let (_, _warm) = sweep_with(MemoStore::open(&dir), &pts, 2);

        let bumped = MemoStore::open_versioned(&dir, s, f, g);
        assert!(bumped.invalidated(), "bump ({s},{f},{g:#x}) must invalidate the file");
        let (_, re) = sweep_with(bumped, &pts, 2);
        assert_eq!(re.sims_run(), pts.len() as u64, "bump ({s},{f},{g:#x}) must re-run all");
    }
}

#[test]
fn single_knob_change_re_runs_only_the_affected_points() {
    let dir = tmpdir("knob");
    let pts = points(&["kmeans", "bfs"], 2, &[1.0, 4.0]);
    let (_, cold) = sweep_with(MemoStore::open(&dir), &pts, 2);
    assert_eq!(cold.sims_run(), pts.len() as u64);

    // Re-declare the identical matrix plus two changed points: one tweak
    // knob (early_refetch off) and one new latency factor. Exactly those
    // two simulate; everything else hits the store.
    let (spec0, dut0, f0) = pts[0];
    let mut eng = Engine::new(2);
    eng.set_store(MemoStore::open(&dir));
    for &(spec, dut, f) in &pts {
        eng.request_tweaked(spec, &dut, f, CfgTweaks::NONE);
    }
    let tweak = CfgTweaks { early_refetch: Some(false), ..CfgTweaks::NONE };
    eng.request_tweaked(spec0, &dut0, f0, tweak);
    eng.request_tweaked(spec0, &dut0, 6.3, CfgTweaks::NONE);
    eng.execute();
    assert_eq!(eng.sims_run(), 2, "only the changed points may simulate");
    assert_eq!(eng.store().unwrap().hits(), pts.len() as u64);
    assert_eq!(eng.store().unwrap().misses(), 2);
    eng.flush_store().unwrap();

    // Third run with the enlarged matrix: now fully warm.
    let mut again = Engine::new(2);
    again.set_store(MemoStore::open(&dir));
    for &(spec, dut, f) in &pts {
        again.request_tweaked(spec, &dut, f, CfgTweaks::NONE);
    }
    again.request_tweaked(spec0, &dut0, f0, tweak);
    again.request_tweaked(spec0, &dut0, 6.3, CfgTweaks::NONE);
    again.execute();
    assert_eq!(again.sims_run(), 0, "the changed points are memoized after one run");
    assert_eq!(again.store().unwrap().hits(), pts.len() as u64 + 2);
}

#[test]
fn on_demand_fallbacks_past_the_plan_horizon_persist_to_the_store() {
    // The adaptive tolerable-latency scans walk past `tolerable::plan`'s
    // declared horizon (4x for BL-class designs, 8x for latency-tolerant
    // ones); those tail points resolve on demand through
    // `Engine::redeem`'s fallback path. Regression pin: the fallback path
    // must record into the memo store exactly like executed batch points,
    // so a second scan over the same design simulates nothing — horizon
    // tail included.
    let dir = tmpdir("fallback");
    let spec = suite::workload_by_name("gaussian").unwrap();
    let dut = DesignUnderTest::new(ltrf::sim::HierarchyKind::Ltrf { plus: true }, false);
    let horizon = *ltrf::coordinator::tolerable::plan_grid(&dut).last().unwrap();

    // One grid point strictly past the horizon: whether or not the
    // early-exit scan reaches it on its own, probing it goes through the
    // on-demand fallback (it was never declared).
    let tail_factor = horizon + 0.5;

    let scan = |dir: &PathBuf| -> ((f64, Stats), Engine) {
        let mut eng = Engine::new(2);
        eng.set_store(MemoStore::open(dir));
        ltrf::coordinator::tolerable::plan(&mut eng, &dut, spec);
        eng.execute();
        let t = ltrf::coordinator::tolerable::measure(&mut eng, &dut, spec, 0.95);
        let tail = eng.point(spec, &dut, tail_factor);
        eng.flush_store().unwrap();
        ((t, tail), eng)
    };

    let (cold_out, cold_eng) = scan(&dir);
    let declared = ltrf::coordinator::tolerable::plan_grid(&dut).len() as u64;
    assert!(
        cold_eng.sims_run() > declared,
        "the past-horizon point must have cost a fallback simulation \
         ({} sims vs {declared} declared) or this test pins nothing",
        cold_eng.sims_run()
    );
    // The flushed file holds the on-demand tail, not just the executed
    // batch: a brand-new store resolves the past-horizon point from disk.
    let mut on_disk = MemoStore::open(&dir);
    assert!(
        on_disk.lookup(spec, &dut, tail_factor, CfgTweaks::NONE).is_some(),
        "the past-horizon fallback point must be in the store file"
    );

    // Second scan, fresh engine, same directory: zero simulations —
    // every point (declared grid AND fallback tail) answers from disk.
    let (warm_out, warm_eng) = scan(&dir);
    assert_eq!(warm_eng.sims_run(), 0, "fallback points must persist across runs");
    assert_eq!(cold_out, warm_out, "scan outcome must round-trip through the store");
}

#[test]
fn corrupted_store_degrades_to_cold_misses_through_the_engine() {
    let dir = tmpdir("corrupt");
    let pts = points(&["kmeans"], 2, &[1.0]);
    let (cold_stats, cold) = sweep_with(MemoStore::open(&dir), &pts, 2);
    assert_eq!(cold.sims_run(), 2);
    let store_path = dir.join(ltrf::coordinator::store::STORE_FILE);

    // Truncate mid-entry: the damaged line is a cold miss (re-simulated,
    // identical stats); the intact entry still hits. Never a panic.
    let text = std::fs::read_to_string(&store_path).unwrap();
    std::fs::write(&store_path, &text[..text.len() - 40]).unwrap();
    let (trunc_stats, trunc) = sweep_with(MemoStore::open(&dir), &pts, 2);
    assert_eq!(trunc.sims_run(), 1, "exactly the mangled entry re-simulates");
    assert_eq!(trunc.store().unwrap().skipped_lines(), 1);
    assert_eq!(trunc.store().unwrap().hits(), 1);
    assert_eq!(cold_stats, trunc_stats, "recovery must reproduce the stats bit-for-bit");

    // Overwrite with a file that is not a store at all: whole-file cold,
    // the sweep re-runs everything and heals the file.
    std::fs::write(&store_path, "totally unrelated\ncontents\n").unwrap();
    let (foreign_stats, foreign) = sweep_with(MemoStore::open(&dir), &pts, 2);
    assert!(foreign.store().unwrap().invalidated());
    assert_eq!(foreign.sims_run(), 2);
    assert_eq!(cold_stats, foreign_stats);
    let (healed_stats, healed) = sweep_with(MemoStore::open(&dir), &pts, 2);
    assert_eq!(healed.sims_run(), 0, "the re-run must have rewritten a valid file");
    assert_eq!(cold_stats, healed_stats);
}
