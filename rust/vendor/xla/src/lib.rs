//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps `xla_extension` (PJRT CPU client + HLO
//! compilation), which cannot be built in the offline container. This stub
//! keeps the exact API surface `ltrf::runtime` compiles against, but every
//! artifact-loading path returns an error, so `PrefetchEvaluator` falls
//! back to its bit-identical pure-rust reference backend. The CPU client
//! itself "comes up" (cheap, no native code) so runtime smoke tests can
//! distinguish "no PJRT at all" from "no compiled artifact".

use std::fmt;

/// Stub error type (implements `std::error::Error` so `?` converts it
/// into `anyhow::Error`).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!("xla stub: {what} unavailable in the offline build (PJRT backend disabled)"))
}

/// PJRT CPU client (stub: constructible, cannot compile executables).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("HLO compilation"))
    }
}

/// Parsed HLO module (stub: never constructed — parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HLO text parsing"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable (stub: unobtainable, methods are type-level only).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// Host literal (stub: carries no data).
#[derive(Clone, Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple4(&self) -> Result<(Literal, Literal, Literal, Literal)> {
        Err(unavailable("literal tuple access"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("literal data access"))
    }
}

impl From<f32> for Literal {
    fn from(_x: f32) -> Self {
        Literal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_up_but_compilation_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert!(!client.platform_name().is_empty());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let _ = comp;
        assert!(PjRtClient::cpu().unwrap().compile(&XlaComputation).is_err());
    }
}
