//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment vendors all dependencies; this stub carries
//! exactly the surface `ltrf` uses: a string-chaining [`Error`], the
//! [`Result`] alias, the [`Context`] extension trait for `Result`/`Option`,
//! and the `anyhow!` / `bail!` macros. Context frames render as
//! `outer: inner`, matching anyhow's `{:#}` alternate format (the only
//! format the crate prints errors with).

use std::fmt;

/// A boxed-string error with a flattened context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context frame (`context: self`).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: any std error converts via `?`. `Error` itself does
// not implement `std::error::Error`, so this blanket impl is coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (subset of anyhow's trait: the error side
/// only needs `Display`, which covers every error type in this tree).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("inner {}", 7))
    }

    #[test]
    fn context_chains_outer_to_inner() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
        let e = fails().with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1: inner 7");
    }

    #[test]
    fn option_context_and_question_mark() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");

        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/nonexistent/anyhow-stub")?;
            Ok(s)
        }
        assert!(io().is_err());
    }
}
