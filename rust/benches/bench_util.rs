//! Shared micro-bench harness (criterion is unavailable offline; this is
//! a deliberate minimal stand-in: warmup + timed iterations + ns/op and
//! throughput reporting, stable enough for before/after comparisons in
//! EXPERIMENTS.md §Perf).

use std::time::Instant;

/// Time `f` and report. Returns mean seconds/iteration.
pub fn bench<F: FnMut() -> u64>(name: &str, iters: u32, mut f: F) -> f64 {
    // Warmup.
    let mut units = 0u64;
    for _ in 0..2 {
        units = f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        units = f();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    let rate = units as f64 / dt;
    println!("{name:<48} {:>10.3} ms/iter   {:>12.0} units/s", dt * 1e3, rate);
    dt
}
