//! Bench: batched prefetch evaluation — PJRT artifact (L1 Pallas kernel
//! via the L2 model) vs the pure-rust reference.
//!
//! Run: `make artifacts && cargo bench --bench prefetch_eval`

mod bench_util;
use bench_util::bench;
use ltrf::runtime::prefetch_eval::{evaluate_reference, LatencyParams, N_BATCH};
use ltrf::runtime::PrefetchEvaluator;
use ltrf::util::{RegSet, Xoshiro256};

fn main() {
    let mut rng = Xoshiro256::seeded(0xBE7C);
    let sets: Vec<RegSet> = (0..N_BATCH)
        .map(|_| {
            let n = rng.range(4, 16);
            RegSet::from_iter((0..n).map(|_| rng.below(256) as u16))
        })
        .collect();
    let mut assign = [0usize; 256];
    for a in assign.iter_mut() {
        *a = rng.below(16) as usize;
    }
    let params = LatencyParams::default();

    bench(&format!("rust reference, {N_BATCH} intervals (rows/s)"), 200, || {
        evaluate_reference(&sets, &assign, params).len() as u64
    });

    match PrefetchEvaluator::load(std::path::Path::new("artifacts")) {
        Ok(ev) => {
            bench(&format!("PJRT artifact, {N_BATCH} intervals (rows/s)"), 20, || {
                ev.evaluate(&sets, &assign, params).unwrap().len() as u64
            });
            // Larger batch across multiple artifact invocations.
            let big: Vec<RegSet> = (0..8 * N_BATCH).map(|i| sets[i % N_BATCH]).collect();
            bench("PJRT artifact, 8x batches (rows/s)", 5, || {
                ev.evaluate(&big, &assign, params).unwrap().len() as u64
            });
        }
        Err(e) => println!("PJRT bench skipped (run `make artifacts`): {e:#}"),
    }
}
