//! Bench: end-to-end regeneration time of each paper table/figure driver
//! (quick context), plus serial-vs-engine comparisons of a shared design
//! matrix so the executor's speedup is tracked in the perf trajectory.
//!
//! Run: `cargo bench --bench paper_tables`

mod bench_util;
use bench_util::bench;
use ltrf::coordinator::engine::Engine;
use ltrf::coordinator::experiments as exp;
use ltrf::sim::HierarchyKind;
use ltrf::workloads::suite;

/// The comparison matrix: 3 workloads × 3 designs × 2 latency factors.
fn matrix_points() -> Vec<(&'static ltrf::workloads::WorkloadSpec, exp::DesignUnderTest, f64)> {
    let workloads = ["kmeans", "gaussian", "pathfinder"];
    let designs = [
        exp::DesignUnderTest::new(HierarchyKind::Baseline, false),
        exp::DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, false),
        exp::DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, true),
    ];
    let mut points = Vec::new();
    for w in workloads {
        let spec = suite::workload_by_name(w).unwrap();
        for d in &designs {
            for factor in [1.0, 4.0] {
                points.push((spec, *d, factor));
            }
        }
    }
    points
}

fn main() {
    let ctx = exp::ExperimentContext::quick();

    // --- per-driver regeneration through the engine (quick context) ---
    // Ticket-API drivers self-execute, so a bench run is one direct call
    // on a fresh engine.
    let drv = |f: fn(&exp::ExperimentContext, &mut Engine) -> ltrf::report::Table| {
        let ctx = ctx.clone();
        move || {
            let mut eng = Engine::new(0);
            f(&ctx, &mut eng).rows.len() as u64
        }
    };
    bench("table1 (TLP capacity demand)", 3, drv(exp::table1));
    bench("table2 (design points)", 10, drv(exp::table2_table));
    bench("fig3 (ideal vs TFET 8x)", 1, drv(exp::fig3));
    bench("fig4 (register cache hit rates)", 1, drv(exp::fig4));
    bench("fig6 (conflict distribution)", 1, drv(exp::fig6));
    bench("fig14 (overall IPC, cfgs #6/#7)", 1, || {
        let mut eng = Engine::new(0);
        exp::fig14(&ctx, &mut eng).iter().map(|t| t.rows.len() as u64).sum()
    });
    bench("fig15 (max tolerable latency)", 1, drv(exp::fig15));
    bench("fig16 (conflicts x N)", 1, || {
        let mut eng = Engine::new(0);
        exp::fig16(&ctx, &mut eng).iter().map(|t| t.rows.len() as u64).sum()
    });
    bench("table4 (interval lengths)", 1, drv(exp::table4));
    bench("fig19 (vs strand-based designs)", 1, drv(exp::fig19));
    bench("headline (config #7 improvement)", 1, || {
        let mut eng = Engine::new(0);
        exp::headline(&ctx, &mut eng).1.rows.len() as u64
    });

    // --- serial legacy path vs the parallel engine on the same matrix ---
    println!();
    let points = matrix_points();
    bench("matrix 3wl x 3designs x 2lat, serial (uncached)", 2, || {
        points.iter().map(|(s, d, f)| d.run(s, *f).instructions).sum()
    });
    for jobs in [1usize, 0] {
        let label = if jobs == 1 {
            "matrix 3wl x 3designs x 2lat, engine --jobs 1"
        } else {
            "matrix 3wl x 3designs x 2lat, engine --jobs auto"
        };
        bench(label, 2, || {
            let mut eng = Engine::new(jobs);
            for (s, d, f) in &points {
                eng.request(*s, d, *f);
            }
            eng.execute();
            points.iter().map(|(s, d, f)| eng.point(*s, d, *f).instructions).sum::<u64>()
        });
    }
}
