//! Bench: end-to-end regeneration time of each paper table/figure driver
//! (quick context). This is the harness a user runs to reproduce the
//! evaluation, so its wall-clock is itself a deliverable.
//!
//! Run: `cargo bench --bench paper_tables`

mod bench_util;
use bench_util::bench;
use ltrf::coordinator::experiments as exp;

fn main() {
    let ctx = exp::ExperimentContext::quick();

    bench("table1 (TLP capacity demand)", 3, || exp::table1(&ctx).rows.len() as u64);
    bench("table2 (design points)", 10, || exp::table2_table(&ctx).rows.len() as u64);
    bench("fig3 (ideal vs TFET 8x)", 1, || exp::fig3(&ctx).rows.len() as u64);
    bench("fig4 (register cache hit rates)", 1, || exp::fig4(&ctx).rows.len() as u64);
    bench("fig6 (conflict distribution)", 1, || exp::fig6(&ctx).rows.len() as u64);
    bench("fig14 (overall IPC, cfgs #6/#7)", 1, || {
        exp::fig14(&ctx).iter().map(|t| t.rows.len() as u64).sum()
    });
    bench("fig15 (max tolerable latency)", 1, || exp::fig15(&ctx).rows.len() as u64);
    bench("fig16 (conflicts x N)", 1, || {
        exp::fig16(&ctx).iter().map(|t| t.rows.len() as u64).sum()
    });
    bench("table4 (interval lengths)", 1, || exp::table4(&ctx).rows.len() as u64);
    bench("fig19 (vs strand-based designs)", 1, || exp::fig19(&ctx).rows.len() as u64);
    bench("headline (config #7 improvement)", 1, || {
        exp::headline(&ctx).1.rows.len() as u64
    });
}
