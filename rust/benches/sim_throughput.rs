//! Bench: cycle-level simulator throughput (warp-instructions/second) per
//! register-file hierarchy — the L3 hot path whose §Perf target is
//! ≥ 10M warp-instructions/s.
//!
//! Run: `cargo bench --bench sim_throughput`

mod bench_util;
use bench_util::bench;
use ltrf::compiler::compile;
use ltrf::sim::{gpu, HierarchyKind, SimConfig};
use ltrf::workloads::{gen, suite};

fn main() {
    let spec = suite::workload_by_name("gaussian").unwrap();
    for kind in [
        HierarchyKind::Baseline,
        HierarchyKind::Rfc,
        HierarchyKind::Shrf,
        HierarchyKind::Ltrf { plus: false },
        HierarchyKind::Ltrf { plus: true },
    ] {
        let cfg = SimConfig::with_hierarchy(kind).with_latency_factor(6.3).normalize_capacity();
        let kernel = gen::build(spec);
        let ck = compile(&kernel, gpu::compile_options(&cfg, true));
        bench(&format!("simulate gaussian on {} @6.3x (winst/s)", kind.name()), 5, || {
            gpu::run(&ck, &cfg).instructions
        });
    }

    // End-to-end including build+compile (the sweep-path unit of work).
    let cfg = SimConfig::with_hierarchy(HierarchyKind::Ltrf { plus: true })
        .with_latency_factor(6.3)
        .normalize_capacity();
    bench("build+compile+simulate gaussian (winst/s)", 5, || {
        gpu::run_workload(spec, &cfg, true).instructions
    });
}
