//! Bench: cycle-level simulator throughput per register-file hierarchy
//! and per *backend* — the L3 hot path whose §Perf target is ≥ 10M
//! warp-instructions/s, now tracked as a trajectory in `BENCH_sim.json`
//! at the repo root.
//!
//! Run: `cargo bench --bench sim_throughput` (or `ltrf bench --json` for
//! the same measurement through the CLI).

mod bench_util;
use bench_util::bench;
use ltrf::bench::{run_bench, BenchOptions};
use ltrf::compiler::compile;
use ltrf::sim::{gpu, HierarchyKind, SimBackend, SimConfig};
use ltrf::workloads::{gen, suite};

fn main() {
    let spec = suite::workload_by_name("gaussian").unwrap();
    for kind in HierarchyKind::ALL {
        let cfg = SimConfig::with_hierarchy(kind).with_latency_factor(6.3).normalize_capacity();
        let kernel = gen::build(spec);
        let ck = compile(&kernel, gpu::compile_options(&cfg, true));
        bench(&format!("simulate gaussian on {} @6.3x (winst/s)", kind.name()), 5, || {
            gpu::run(&ck, &cfg).instructions
        });
    }

    // Backend comparison on the same hot point (1 SM: the parallel
    // backend's serial two-phase loop vs the inline reference).
    {
        let base = SimConfig::with_hierarchy(HierarchyKind::Ltrf { plus: true })
            .with_latency_factor(6.3)
            .normalize_capacity();
        let kernel = gen::build(spec);
        let ck = compile(&kernel, gpu::compile_options(&base, true));
        for (label, backend, threads) in [
            ("reference", SimBackend::Reference, 1usize),
            ("parallel t1", SimBackend::Parallel, 1),
        ] {
            let cfg = SimConfig { backend, sim_threads: threads, ..base };
            bench(&format!("gaussian LTRF+ @6.3x, {label} (winst/s)"), 5, || {
                gpu::run(&ck, &cfg).instructions
            });
        }
        // Multi-SM: where the threaded step phase earns its keep.
        for (label, backend, threads) in [
            ("reference", SimBackend::Reference, 1usize),
            ("parallel t1", SimBackend::Parallel, 1),
            ("parallel t4", SimBackend::Parallel, 4),
        ] {
            let cfg = SimConfig { num_sms: 8, backend, sim_threads: threads, ..base };
            bench(&format!("gaussian LTRF+ @6.3x x8 SMs, {label} (winst/s)"), 3, || {
                gpu::run(&ck, &cfg).instructions
            });
        }
    }

    // End-to-end including build+compile (the sweep-path unit of work).
    let cfg = SimConfig::with_hierarchy(HierarchyKind::Ltrf { plus: true })
        .with_latency_factor(6.3)
        .normalize_capacity();
    bench("build+compile+simulate gaussian (winst/s)", 5, || {
        gpu::run_workload(spec, &cfg, true).instructions
    });

    // The committed trajectory: both backends over the fig14 matrix,
    // written to BENCH_sim.json at the repo root.
    let report = run_bench(&BenchOptions::default());
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = root.join("BENCH_sim.json");
    std::fs::write(&path, report.to_json()).expect("write BENCH_sim.json");
    if let Some(s) = report.fig14_speedup() {
        println!(
            "fig14 matrix: parallel x{} is {s:.2}x reference wall time -> {}",
            report.sim_threads,
            path.display()
        );
    }
}
