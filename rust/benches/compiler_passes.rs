//! Bench: compiler pass throughput over the benchmark suite.
//!
//! Run: `cargo bench --bench compiler_passes`

mod bench_util;
use bench_util::bench;
use ltrf::compiler::{
    coloring, icg, intervals, merge, renumber, BankMap, CompileOptions, PassManager,
};
use ltrf::workloads::{gen, suite};

fn main() {
    let kernels: Vec<_> = suite::suite().iter().map(|s| gen::build(s)).collect();
    let insts: u64 = kernels.iter().map(|k| k.num_insts() as u64).sum();
    println!("suite: {} kernels, {} instructions\n", kernels.len(), insts);

    bench("interval formation (Alg 1), suite", 20, || {
        let mut n = 0u64;
        for k in &kernels {
            let mut k = k.clone();
            let ia = intervals::form_intervals(&mut k, 16);
            n += ia.intervals.len() as u64;
        }
        n
    });

    bench("interval reduction (Alg 2), suite", 20, || {
        let mut n = 0u64;
        for k in &kernels {
            let mut kc = k.clone();
            let p1 = intervals::form_intervals(&mut kc, 16);
            let ia = merge::reduce(&kc, p1);
            n += ia.intervals.len() as u64;
        }
        n
    });

    bench("ICG build + Chaitin coloring, suite", 20, || {
        let mut n = 0u64;
        for k in &kernels {
            let mut kc = k.clone();
            let p1 = intervals::form_intervals(&mut kc, 16);
            let ia = merge::reduce(&kc, p1);
            let g = icg::build(&ia);
            let col = coloring::chaitin(&g, 16);
            n += col.color.iter().flatten().count() as u64;
        }
        n
    });

    bench("full pipeline incl. renumbering, suite", 10, || {
        let mut n = 0u64;
        for k in &kernels {
            let ck = ltrf::compiler::compile(k, CompileOptions::ltrf_conf(16));
            n += ck.intervals.intervals.len() as u64;
        }
        n
    });

    // The pass manager's sweep shape: every kernel compiled as LTRF,
    // LTRF_conf, and a second bank map — cold recomputes everything,
    // warm shares the whole DAG through the analysis cache.
    let sweep = |mgr: &PassManager| {
        let mut n = 0u64;
        for k in &kernels {
            for opts in [
                CompileOptions::ltrf(16),
                CompileOptions::ltrf_conf(16),
                CompileOptions { bank_map: BankMap::Block, ..CompileOptions::ltrf_conf(16) },
            ] {
                let ck = mgr.compile(k, opts).expect("valid options");
                n += ck.intervals.intervals.len() as u64;
            }
        }
        n
    };

    bench("pass-manager sweep, cold cache, suite", 10, || {
        let mgr = PassManager::new();
        sweep(&mgr)
    });

    let warm = PassManager::new();
    sweep(&warm);
    bench("pass-manager sweep, warm cache, suite", 10, || sweep(&warm));

    bench("bank-conflict histogram, suite", 50, || {
        let mut n = 0u64;
        for k in &kernels {
            let ck = ltrf::compiler::compile(k, CompileOptions::ltrf(16));
            let h = renumber::conflict_histogram(
                ck.intervals.intervals.iter().map(|i| &i.working_set),
                16,
                BankMap::Interleave,
            );
            n += h.iter().sum::<usize>() as u64;
        }
        n
    });
}
