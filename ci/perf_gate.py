#!/usr/bin/env python3
"""Perf-regression gate for the simulator-throughput trajectory.

Compares a freshly measured ``BENCH_sim.json`` (produced by CI's
perf-smoke step) against the committed baseline copy, and fails when any
tracked family regresses past a threshold:

* ``fig14`` rows — warp-instruction throughput (higher is better);
* ``replay`` rows — the replay hot loop and its dense twin, also by
  winst/s, so the interval-replay engine's headline win cannot silently
  erode;
* ``store`` / ``frontier`` / ``compile`` families — wall seconds (lower
  is better), with an absolute slack floor so millisecond-scale warm
  rows do not flap on runner noise.

Arming rule: the threshold only fires when the committed baseline says
``"provenance": "measured"``. The growth container that authors this
repo has no Rust toolchain, so the committed file may instead carry a
hand-written estimate provenance ("seed-estimate: ..."); estimates are
printed for context but can neither fail nor vouch for a real
measurement. Committing the CI artifact (which `bench.rs` always stamps
``measured``) arms the gate.

A measured baseline must also carry nonzero epoch-core diagnostics
(``epoch_commit_phases_skipped``), nonzero interval-replay diagnostics
(``epoch_replay_fast_forwards``), and nonzero ensemble-replay
diagnostics (``epoch_replay_ensemble_fast_forwards``) — a baseline
"measured" with commit batching, the replay engine, or its multi-warp
ensemble path dead would set a dishonest bar.

Usage: perf_gate.py BASELINE.json CURRENT.json [--threshold=0.15]
Exit 0 = pass (or disarmed), 1 = regression, 2 = usage/shape error.
"""

import json
import sys

# Throughput rows the gate tracks (higher winst/s is better): the
# headline trajectory number is the threaded fig14 matrix, but
# single-thread rows are gated too so a serial-path regression cannot
# hide behind parallel scaling, and the replay pair so the interval
# engine's fast-forward win stays honest relative to its dense twin.
TRACKED = [
    ("fig14_matrix", "parallel", None),  # None = the report's sim_threads
    ("fig14_matrix", "parallel", 1),
    ("fig14_matrix", "reference", 1),
    ("replay_hot_loop", "reference", 1),
    ("replay_hot_loop_dense", "reference", 1),
    ("replay_hot_loop_mw", "reference", 1),
    ("replay_hot_loop_mw_dense", "reference", 1),
]

# Wall-seconds families (lower is better): (report key, row name, mode).
# Warm rows are a handful of milliseconds in quick mode, so a relative
# threshold alone would flap on runner noise; a row only fails when it
# is BOTH >threshold slower and more than WALL_SLACK_SECONDS slower in
# absolute terms.
WALL_FAMILIES = [
    ("store", "store_sweep", "cold"),
    ("store", "store_sweep", "warm"),
    ("frontier", "frontier_search", "cold"),
    ("frontier", "frontier_search", "warm"),
    ("compile", "compile_throughput", "cold"),
    ("compile", "compile_throughput", "warm"),
]
WALL_SLACK_SECONDS = 0.05


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"perf_gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def find_row(report, name, backend, threads):
    if threads is None:
        threads = report.get("sim_threads", 4)
    for e in report.get("entries", []):
        if (
            e.get("name") == name
            and e.get("backend") == backend
            and e.get("sim_threads") == threads
        ):
            return e, threads
    return None, threads


def find_family_row(report, family, name, mode):
    for e in report.get(family, []):
        if e.get("name") == name and e.get("mode") == mode:
            return e
    return None


def winst_per_second(entry):
    wall = max(float(entry.get("wall_seconds", 0.0)), 1e-12)
    return float(entry.get("instructions", 0)) / wall


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    threshold = 0.15
    for a in argv[1:]:
        if a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline = load(args[0])
    current = load(args[1])

    provenance = str(baseline.get("provenance", ""))
    armed = provenance == "measured"

    print(f"perf_gate: baseline {args[0]} provenance={provenance!r} " f"armed={armed}")
    worst = None
    compared = 0
    for name, backend, threads in TRACKED:
        base_row, bt = find_row(baseline, name, backend, threads)
        cur_row, ct = find_row(current, name, backend, threads)
        if base_row is None or cur_row is None:
            # Pre-v4 baselines have no replay rows and pre-v5 no mw
            # (ensemble) rows; a missing pair only disarms itself, never
            # the fig14 trajectory.
            print(f"  {name}/{backend}@{bt}t: missing row " f"(baseline={base_row is not None}, current={cur_row is not None})")
            continue
        base = winst_per_second(base_row)
        cur = winst_per_second(cur_row)
        ratio = cur / max(base, 1e-12)
        compared += 1
        print(f"  {name}/{backend}@{ct}t: baseline {base:,.0f} winst/s, " f"current {cur:,.0f} winst/s ({ratio:.2f}x)")
        if worst is None or ratio < worst:
            worst = ratio

    wall_fail = []
    for family, name, mode in WALL_FAMILIES:
        base_row = find_family_row(baseline, family, name, mode)
        cur_row = find_family_row(current, family, name, mode)
        if base_row is None or cur_row is None:
            print(f"  {family}/{name}/{mode}: missing row " f"(baseline={base_row is not None}, current={cur_row is not None})")
            continue
        base = float(base_row.get("wall_seconds", 0.0))
        cur = float(cur_row.get("wall_seconds", 0.0))
        ratio = cur / max(base, 1e-12)
        compared += 1
        slow = cur > base * (1.0 + threshold) and cur - base > WALL_SLACK_SECONDS
        print(f"  {family}/{name}/{mode}: baseline {base * 1e3:.2f} ms, " f"current {cur * 1e3:.2f} ms ({ratio:.2f}x wall{', SLOW' if slow else ''})")
        if slow:
            wall_fail.append(f"{family}/{name}/{mode} {ratio:.2f}x wall")

    if not armed:
        print("perf_gate: baseline is not a committed measurement; comparison is informational only (commit the CI bench artifact to arm the gate)")
        return 0

    if baseline.get("epoch_commit_phases_skipped", 0) <= 0:
        print("perf_gate: measured baseline reports zero epoch_commit_phases_skipped — commit batching was dead when it was captured; refusing it as a bar", file=sys.stderr)
        return 1

    if baseline.get("epoch_replay_fast_forwards", 0) <= 0:
        print("perf_gate: measured baseline reports zero epoch_replay_fast_forwards — the interval-replay engine was dead when it was captured; refusing it as a bar", file=sys.stderr)
        return 1

    if baseline.get("epoch_replay_ensemble_fast_forwards", 0) <= 0:
        print("perf_gate: measured baseline reports zero epoch_replay_ensemble_fast_forwards — the multi-warp ensemble replay path was dead when it was captured; refusing it as a bar", file=sys.stderr)
        return 1

    if compared == 0:
        print("perf_gate: no comparable rows between baseline and current", file=sys.stderr)
        return 1
    if wall_fail:
        print(f"perf_gate: FAIL — wall-time families regressed past {threshold:.0%} (+{WALL_SLACK_SECONDS * 1e3:.0f} ms slack): {'; '.join(wall_fail)}", file=sys.stderr)
        return 1
    if worst is not None and worst < 1.0 - threshold:
        print(f"perf_gate: FAIL — tracked throughput dropped to {worst:.2f}x of the measured baseline (threshold {1.0 - threshold:.2f}x)", file=sys.stderr)
        return 1
    print(f"perf_gate: OK ({compared} rows; worst throughput ratio " f"{worst:.2f}x, threshold {1.0 - threshold:.2f}x)" if worst is not None else f"perf_gate: OK ({compared} wall rows within threshold)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
