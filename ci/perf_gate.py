#!/usr/bin/env python3
"""Perf-regression gate for the simulator-throughput trajectory.

Compares a freshly measured ``BENCH_sim.json`` (produced by CI's
perf-smoke step) against the committed baseline copy, and fails when the
fig14-matrix warp-instruction throughput regresses past a threshold.

Arming rule: the threshold only fires when the committed baseline says
``"provenance": "measured"``. The growth container that authors this
repo has no Rust toolchain, so the committed file may instead carry a
hand-written estimate provenance ("seed-estimate: ..."); estimates are
printed for context but can neither fail nor vouch for a real
measurement. Committing the CI artifact (which `bench.rs` always stamps
``measured``) arms the gate.

A measured baseline must also carry nonzero epoch-core diagnostics
(``epoch_commit_phases_skipped``) — a baseline "measured" with commit
batching dead would set a dishonest bar.

Usage: perf_gate.py BASELINE.json CURRENT.json [--threshold 0.15]
Exit 0 = pass (or disarmed), 1 = regression, 2 = usage/shape error.
"""

import json
import sys

# Rows the gate tracks: the headline trajectory number is the threaded
# fig14 matrix, but single-thread rows are gated too so a serial-path
# regression cannot hide behind parallel scaling.
TRACKED = [
    ("fig14_matrix", "parallel", None),  # None = the report's sim_threads
    ("fig14_matrix", "parallel", 1),
    ("fig14_matrix", "reference", 1),
]


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"perf_gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def find_row(report, name, backend, threads):
    if threads is None:
        threads = report.get("sim_threads", 4)
    for e in report.get("entries", []):
        if (
            e.get("name") == name
            and e.get("backend") == backend
            and e.get("sim_threads") == threads
        ):
            return e, threads
    return None, threads


def winst_per_second(entry):
    wall = max(float(entry.get("wall_seconds", 0.0)), 1e-12)
    return float(entry.get("instructions", 0)) / wall


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    threshold = 0.15
    for a in argv[1:]:
        if a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline = load(args[0])
    current = load(args[1])

    provenance = str(baseline.get("provenance", ""))
    armed = provenance == "measured"

    print(f"perf_gate: baseline {args[0]} provenance={provenance!r} " f"armed={armed}")
    worst = None
    for name, backend, threads in TRACKED:
        base_row, bt = find_row(baseline, name, backend, threads)
        cur_row, ct = find_row(current, name, backend, threads)
        if base_row is None or cur_row is None:
            print(f"  {name}/{backend}@{bt}t: missing row " f"(baseline={base_row is not None}, current={cur_row is not None})")
            continue
        base = winst_per_second(base_row)
        cur = winst_per_second(cur_row)
        ratio = cur / max(base, 1e-12)
        print(f"  {name}/{backend}@{ct}t: baseline {base:,.0f} winst/s, " f"current {cur:,.0f} winst/s ({ratio:.2f}x)")
        if worst is None or ratio < worst:
            worst = ratio

    if not armed:
        print("perf_gate: baseline is not a committed measurement; comparison is informational only (commit the CI bench artifact to arm the gate)")
        return 0

    if baseline.get("epoch_commit_phases_skipped", 0) <= 0:
        print("perf_gate: measured baseline reports zero epoch_commit_phases_skipped — commit batching was dead when it was captured; refusing it as a bar", file=sys.stderr)
        return 1

    if worst is None:
        print("perf_gate: no comparable rows between baseline and current", file=sys.stderr)
        return 1
    if worst < 1.0 - threshold:
        print(f"perf_gate: FAIL — fig14 throughput dropped to {worst:.2f}x of the measured baseline (threshold {1.0 - threshold:.2f}x)", file=sys.stderr)
        return 1
    print(f"perf_gate: OK (worst tracked ratio {worst:.2f}x, threshold {1.0 - threshold:.2f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
