//! Design-space walk (§7.4's conclusion): with LTRF making slow register
//! files tolerable, sweep the Table-2 technologies and report the
//! performance / power / area landscape an architect would navigate.
//!
//! Run: `cargo run --release --example design_space [--quick]`

use ltrf::coordinator::experiments::{baseline_ipc, DesignUnderTest, ExperimentContext};
use ltrf::coordinator::sweep::{gmean, parallel_map};
use ltrf::report::Table;
use ltrf::sim::HierarchyKind;
use ltrf::timing::table2;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ctx = if quick { ExperimentContext::quick() } else { ExperimentContext::default() };

    let mut t = Table::new(
        "Design space: Table-2 configs under BL vs LTRF_conf (suite gmean, normalized IPC)",
        &[
            "cfg",
            "tech",
            "capacity",
            "latency",
            "power",
            "area",
            "BL",
            "LTRF_conf",
            "perf/power (LTRF)",
        ],
    );
    for d in table2() {
        let factor = d.latency();
        let cap = d.warp_registers();
        let rows = parallel_map(ctx.workloads(), |spec| {
            let base = baseline_ipc(spec);
            let bl = DesignUnderTest::new(HierarchyKind::Baseline, false)
                .with_capacity(cap)
                .run(spec, factor)
                .ipc()
                / base;
            let lt = DesignUnderTest::new(HierarchyKind::Ltrf { plus: true }, true)
                .with_capacity(cap)
                .run(spec, factor)
                .ipc()
                / base;
            (bl, lt)
        });
        let bl = gmean(&rows.iter().map(|r| r.0).collect::<Vec<_>>());
        let lt = gmean(&rows.iter().map(|r| r.1).collect::<Vec<_>>());
        t.row(vec![
            format!("#{}", d.id),
            d.tech.name().into(),
            format!("{:.0}KB", d.capacity_bytes() as f64 / 1024.0),
            format!("{:.2}x", factor),
            format!("{:.2}x", d.power()),
            format!("{:.2}x", d.area()),
            format!("{bl:.2}"),
            format!("{lt:.2}"),
            format!("{:.2}", lt / d.power()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Reading: LTRF keeps high-latency/high-density designs (#6, #7) competitive,\n\
         opening the power/area optimization space the paper argues for (§7.4)."
    );
}
