//! END-TO-END driver: the full system on the whole benchmark suite,
//! reproducing the paper's headline claim (abstract / §7.1):
//!
//!   "LTRF [with register renumbering], when implemented with an 8× larger
//!    yet 6.3× slower main register file [config #7, DWM], improves overall
//!    GPU performance by 34% on average."
//!
//! Every layer composes here: the workload generator builds the 14
//! kernels, the compiler forms register-intervals + renumbers registers
//! (prefetch vectors validated by the PJRT-compiled Pallas artifact when
//! present), and the cycle-level simulator produces the IPC numbers.
//!
//! Run: `cargo run --release --example e2e_headline` (add `--quick` for
//! the 5-workload subset). Results are recorded in EXPERIMENTS.md.

use ltrf::coordinator::engine::Engine;
use ltrf::coordinator::experiments::{headline, ExperimentContext};
use ltrf::runtime::PrefetchEvaluator;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ctx = if quick { ExperimentContext::quick() } else { ExperimentContext::default() };

    // Surface which backend validates the prefetch vectors.
    let ev = PrefetchEvaluator::load_or_reference(std::path::Path::new("artifacts"));
    println!(
        "prefetch evaluator backend: {}",
        if ev.is_pjrt() {
            "PJRT (AOT JAX/Pallas artifact)"
        } else {
            "rust reference (run `make artifacts`)"
        }
    );

    let t0 = std::time::Instant::now();
    // Ticket-API engine run: the headline driver declares its points
    // (suite × {baseline, config #7}), executes them as one deduplicated
    // parallel job matrix, then redeems the tickets for the table.
    let mut eng = Engine::new(ctx.jobs);
    let (improvement, table) = headline(&ctx, &mut eng);
    println!("{}", table.render());
    eprintln!("{}", eng.summary());
    println!(
        "LTRF_conf on config #7 (DWM, 2MB, 6.3x): mean IPC improvement +{:.1}% (paper: +34%)",
        improvement * 100.0
    );
    println!("elapsed: {:.1}s", t0.elapsed().as_secs_f64());
    assert!(improvement > 0.0, "end-to-end run must show an improvement");
}
