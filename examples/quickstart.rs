//! Quickstart: compile a small kernel, simulate it on the baseline and on
//! LTRF with a slow 8× register file, and print what happened.
//!
//! Run: `cargo run --release --example quickstart`

use ltrf::compiler::{compile, CompileOptions};
use ltrf::ir::parser;
use ltrf::sim::{gpu, HierarchyKind, SimConfig};

/// The paper's Listing 1: compare two 100-element arrays.
const LISTING1: &str = r#"
.kernel listing1
  mov r0, #0x1000
  mov r1, #0x2000
  mov r2, #0
  mov r3, #100
L1:
  ld.global r4, [r0]
  ld.global r5, [r1]
  setp.eq p0, r4, r5
  @!p0 bra L2
  add r0, r0, #4
  add r1, r1, #4
  add r2, r2, #1
  setp.lt p1, r2, r3
  @p1 bra L1
  mov r6, #1
  bra L3
L2:
  mov r6, #0
L3:
  st.global [r6], r6
  exit
"#;

fn main() {
    // 1. Parse and compile with register-interval formation (N = 16).
    let kernel = parser::parse(LISTING1).expect("parse");
    let ck = compile(&kernel, CompileOptions::ltrf_conf(16));
    println!(
        "kernel `{}`: {} blocks, {} instructions",
        ck.kernel.name,
        ck.kernel.num_blocks(),
        ck.kernel.num_insts()
    );
    println!("register-intervals: {}", ck.intervals.intervals.len());
    for iv in &ck.intervals.intervals {
        println!(
            "  interval {} (header {}): {} blocks, working set {:?}",
            iv.id,
            ck.kernel.blocks[iv.header].label,
            iv.blocks.len(),
            iv.working_set
        );
    }
    println!(
        "conflict-free prefetches after renumbering: {:.0}%\n",
        ck.conflict_free_fraction() * 100.0
    );

    // 2. Simulate: conventional RF vs LTRF, both with a 6.3×-latency MRF
    //    (the Table-2 DWM design point).
    for kind in [HierarchyKind::Baseline, HierarchyKind::Ltrf { plus: true }] {
        let cfg = SimConfig::with_hierarchy(kind).with_latency_factor(6.3).normalize_capacity();
        let ck = compile(&kernel, gpu::compile_options(&cfg, true));
        let stats = gpu::run(&ck, &cfg);
        println!(
            "{:>5} @ 6.3x latency: IPC {:.3}  (MRF reads {}, cache reads {}, prefetches {})",
            kind.name(),
            stats.ipc(),
            stats.mrf_reads,
            stats.cache_reads,
            stats.prefetch_ops
        );
    }
}
