//! Compiler deep-dive: walk the §4.3 example through the whole pipeline —
//! interval formation (Alg. 1), reduction (Alg. 2), ICG construction,
//! Chaitin coloring, and register renumbering — printing each stage.
//!
//! Run: `cargo run --release --example compiler_inspect [file.ltrf]`

use ltrf::compiler::{coloring, icg, intervals, merge, renumber, BankMap};
use ltrf::ir::parser;

const DEFAULT: &str = r#"
.kernel walkthrough
  mov r0, #0x1000
  mov r1, #0x2000
  mov r2, #0
  mov r3, #100
L1:
  ld.global r4, [r0]
  ld.global r5, [r1]
  setp.eq p0, r4, r5
  @!p0 bra L2
  add r0, r0, #4
  add r1, r1, #4
  add r2, r2, #1
  setp.lt p1, r2, r3
  @p1 bra L1
  mov r6, #1
  bra L3
L2:
  mov r6, #0
L3:
  st.global [r6], r6
  exit
"#;

fn main() {
    let src = std::env::args()
        .nth(1)
        .map(|p| std::fs::read_to_string(p).expect("read kernel file"))
        .unwrap_or_else(|| DEFAULT.to_string());
    let (n, banks) = (4usize, 4usize); // §4.3 uses 4 regs/interval, 4 banks

    let mut kernel = parser::parse(&src).expect("parse");
    println!("=== input ===\n{}", kernel.display());

    // Pass 1 (Algorithm 1).
    let pass1 = intervals::form_intervals(&mut kernel, n);
    println!("=== pass 1: {} intervals ===", pass1.intervals.len());
    for iv in &pass1.intervals {
        println!("  iv{} header={} ws={:?}", iv.id, kernel.blocks[iv.header].label, iv.working_set);
    }

    // Pass 2 (Algorithm 2, to fixpoint).
    let ia = merge::reduce(&kernel, pass1);
    println!("=== pass 2: {} intervals ===", ia.intervals.len());
    for iv in &ia.intervals {
        let c = renumber::bank_conflicts(&iv.working_set, banks, BankMap::Interleave);
        println!(
            "  iv{} header={} ws={:?} conflicts={}",
            iv.id,
            kernel.blocks[iv.header].label,
            iv.working_set,
            c
        );
    }

    // ICG + coloring (§4.2).
    let g = icg::build(&ia);
    println!("=== ICG: {} nodes, {} edges ===", g.nodes.len(), g.num_edges());
    for r in g.nodes.iter() {
        println!("  r{r}: conflicts with {:?}", g.adj[r as usize]);
    }
    let col = coloring::chaitin(&g, banks);
    println!("=== coloring ({banks} colors, forced={}) ===", col.forced);
    for r in g.nodes.iter() {
        println!("  r{r} -> bank {}", col.color[r as usize].unwrap());
    }

    // Renumbering.
    let before: usize = ia
        .intervals
        .iter()
        .map(|i| renumber::bank_conflicts(&i.working_set, banks, BankMap::Interleave))
        .sum();
    let rn = renumber::renumber(&mut kernel, &col, banks, BankMap::Interleave);
    let after: usize = ia
        .intervals
        .iter()
        .map(|i| {
            renumber::bank_conflicts(
                &renumber::remap_set(&i.working_set, &rn.remap),
                banks,
                BankMap::Interleave,
            )
        })
        .sum();
    println!("=== renumbered (conflicts {before} -> {after}) ===\n{}", kernel.display());
}
