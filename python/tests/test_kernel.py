"""Kernel-vs-oracle correctness: the core L1 signal.

The Pallas kernel (interpret mode) must agree exactly with the pure-jnp
reference for arbitrary working sets and bank assignments; hypothesis
sweeps contents, densities, and bank maps.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.prefetch_eval import (
    LANES,
    MAX_REGS,
    N_BATCH,
    TILE_N,
    prefetch_eval_pallas,
)
from compile.kernels.ref import prefetch_eval_ref, prefetch_latency_ref


def onehot_from_assignment(assign, num_banks=16):
    oh = np.zeros((MAX_REGS, num_banks), dtype=np.float32)
    oh[np.arange(MAX_REGS), assign % num_banks] = 1.0
    return oh


def pack_sets(sets, n):
    """List of register-id lists → uint32[n, LANES] bit-vectors."""
    ws = np.zeros((n, LANES), dtype=np.uint32)
    for i, regs in enumerate(sets):
        for r in regs:
            ws[i, r // 32] |= np.uint32(1) << np.uint32(r % 32)
    return ws


def test_empty_batch_is_zero():
    ws = np.zeros((TILE_N, LANES), dtype=np.uint32)
    oh = onehot_from_assignment(np.arange(MAX_REGS))
    counts, maxocc, total = prefetch_eval_pallas(ws, oh)
    assert counts.shape == (TILE_N, 16)
    np.testing.assert_array_equal(np.asarray(counts), 0.0)
    np.testing.assert_array_equal(np.asarray(maxocc), 0.0)
    np.testing.assert_array_equal(np.asarray(total), 0.0)


def test_known_conflicts():
    # r0, r16, r32 share bank 0 under interleave: occupancy 3.
    ws = pack_sets([[0, 16, 32], [0, 1, 2, 3]], TILE_N)
    oh = onehot_from_assignment(np.arange(MAX_REGS))
    counts, maxocc, total = prefetch_eval_pallas(ws, oh)
    assert counts[0, 0] == 3.0
    assert maxocc[0] == 3.0
    assert total[0] == 3.0
    assert maxocc[1] == 1.0  # four distinct banks
    assert total[1] == 4.0


def test_full_working_set():
    ws = np.full((TILE_N, LANES), 0xFFFFFFFF, dtype=np.uint32)
    oh = onehot_from_assignment(np.arange(MAX_REGS))
    counts, maxocc, total = prefetch_eval_pallas(ws, oh)
    # 256 registers over 16 banks: 16 per bank.
    np.testing.assert_array_equal(np.asarray(counts), 16.0)
    assert maxocc[0] == 16.0
    assert total[0] == 256.0


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_pallas_matches_ref_random(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    density = data.draw(st.floats(0.0, 1.0))
    ws = (rng.random((TILE_N, LANES)) < density).astype(np.uint32)
    # Pack random 32-bit lanes directly.
    ws = rng.integers(0, 2**32, size=(TILE_N, LANES), dtype=np.uint64).astype(
        np.uint32
    ) * ws
    assign = rng.integers(0, 16, size=MAX_REGS)
    oh = onehot_from_assignment(assign)
    pc, pm, pt = prefetch_eval_pallas(ws, oh)
    rc, rm, rt = prefetch_eval_ref(ws, oh)
    np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(pm), np.asarray(rm))
    np.testing.assert_array_equal(np.asarray(pt), np.asarray(rt))


@settings(max_examples=10, deadline=None)
@given(batch_tiles=st.integers(1, 8))
def test_batch_shapes(batch_tiles):
    n = batch_tiles * TILE_N
    ws = np.zeros((n, LANES), dtype=np.uint32)
    ws[:, 0] = 0b1011
    oh = onehot_from_assignment(np.arange(MAX_REGS))
    counts, maxocc, total = prefetch_eval_pallas(ws, oh)
    assert counts.shape == (n, 16)
    np.testing.assert_array_equal(np.asarray(total), 3.0)


def test_non_tile_multiple_rejected():
    ws = np.zeros((TILE_N + 1, LANES), dtype=np.uint32)
    oh = onehot_from_assignment(np.arange(MAX_REGS))
    with pytest.raises(AssertionError):
        prefetch_eval_pallas(ws, oh)


def test_latency_model_reference():
    # occupancy 3 at 13 cycles + ceil(5/2) transfer + 4 = 46.
    lat = prefetch_latency_ref(
        np.float32(3.0), np.float32(5.0), 13.0, 2.0, 4.0
    )
    assert float(lat) == 3 * 13 + 3 + 4
    # Empty set costs nothing.
    assert float(prefetch_latency_ref(np.float32(0), np.float32(0), 13.0, 2.0, 4.0)) == 0.0


def test_n_batch_geometry():
    assert N_BATCH % TILE_N == 0
    assert LANES * 32 == MAX_REGS
