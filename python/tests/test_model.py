"""L2 model + AOT lowering tests: shapes, latency semantics, HLO export."""

import os
import tempfile

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.aot import build_artifacts, to_hlo_text
from compile.kernels.prefetch_eval import LANES, MAX_REGS, N_BATCH
from compile.model import example_args, prefetch_eval_model
from compile.kernels.ref import prefetch_eval_ref, prefetch_latency_ref


def onehot(assign, num_banks=16):
    oh = np.zeros((MAX_REGS, num_banks), dtype=np.float32)
    oh[np.arange(MAX_REGS), assign % num_banks] = 1.0
    return oh


def batch_with(sets):
    ws = np.zeros((N_BATCH, LANES), dtype=np.uint32)
    for i, regs in enumerate(sets):
        for r in regs:
            ws[i, r // 32] |= np.uint32(1) << np.uint32(r % 32)
    return ws


def test_model_shapes_and_padding():
    ws = batch_with([[0, 1, 2], [0, 16]])
    oh = onehot(np.arange(MAX_REGS))
    counts, conflicts, latency, total = prefetch_eval_model(
        ws, oh, np.float32(13.0), np.float32(2.0), np.float32(4.0)
    )
    assert counts.shape == (N_BATCH, 16)
    assert conflicts.shape == (N_BATCH,)
    # Padded (empty) rows contribute nothing.
    assert float(latency[2]) == 0.0
    assert float(conflicts[2]) == 0.0
    # Row 1: r0 and r16 share bank 0 → one conflict.
    assert float(conflicts[1]) == 1.0
    assert float(total[0]) == 3.0


def test_model_latency_matches_reference():
    ws = batch_with([[0, 16, 32, 1, 2]])
    oh = onehot(np.arange(MAX_REGS))
    mrf, rate, lat = np.float32(13.0), np.float32(2.0), np.float32(4.0)
    _, _, latency, total = prefetch_eval_model(ws, oh, mrf, rate, lat)
    _, maxocc, t = prefetch_eval_ref(ws, oh)
    expect = prefetch_latency_ref(maxocc, t, mrf, rate, lat)
    np.testing.assert_array_equal(np.asarray(latency), np.asarray(expect))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_model_conflicts_property(seed):
    rng = np.random.default_rng(seed)
    ws = rng.integers(0, 2**32, size=(N_BATCH, LANES), dtype=np.uint64).astype(np.uint32)
    # Sparsify: most rows small.
    ws[rng.random(N_BATCH) < 0.5] = 0
    assign = rng.integers(0, 16, size=MAX_REGS)
    oh = onehot(assign)
    counts, conflicts, latency, total = prefetch_eval_model(
        ws, oh, np.float32(2.0), np.float32(2.0), np.float32(4.0)
    )
    counts = np.asarray(counts)
    conflicts = np.asarray(conflicts)
    total = np.asarray(total)
    # Conflicts = max occupancy − 1 for non-empty rows.
    nonempty = total > 0
    np.testing.assert_array_equal(
        conflicts[nonempty], counts[nonempty].max(axis=1) - 1.0
    )
    np.testing.assert_array_equal(conflicts[~nonempty], 0.0)
    # Popcount conservation.
    np.testing.assert_array_equal(counts.sum(axis=1), total)
    # Latency positive iff non-empty.
    lat = np.asarray(latency)
    assert (lat[nonempty] > 0).all()
    np.testing.assert_array_equal(lat[~nonempty], 0.0)


def test_hlo_text_export():
    import jax

    lowered = jax.jit(prefetch_eval_model).lower(*example_args())
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    # Interchange constraint: text, parseable, with the model entry.
    assert "ENTRY" in text


def test_build_artifacts_writes_files():
    with tempfile.TemporaryDirectory() as d:
        arts = build_artifacts(d)
        assert "prefetch_eval" in arts
        path = arts["prefetch_eval"]
        assert os.path.exists(path)
        assert os.path.getsize(path) > 1000
        with open(path) as f:
            assert "HloModule" in f.read(200)
