"""AOT lowering: JAX/Pallas model → HLO *text* → artifacts/.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which
the rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo and the repo README.

Run once per build: ``make artifacts`` (no-op when inputs are unchanged).
Python never runs on the simulator's request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import example_args, prefetch_eval_model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifacts(out_dir: str) -> dict:
    """Lower every artifact; returns {name: path}."""
    os.makedirs(out_dir, exist_ok=True)
    artifacts = {}

    lowered = jax.jit(prefetch_eval_model).lower(*example_args())
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "prefetch_eval.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    artifacts["prefetch_eval"] = path
    return artifacts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    for name, path in build_artifacts(args.out_dir).items():
        size = os.path.getsize(path)
        print(f"wrote {name}: {path} ({size} bytes)")


if __name__ == "__main__":
    main()
