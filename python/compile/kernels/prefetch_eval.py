"""L1 — Pallas kernel: batched prefetch bank-occupancy evaluation.

The hot analysis of the LTRF stack: given a batch of register-interval
working-set bit-vectors (one 256-bit vector per prefetch operation) and a
register→bank assignment, compute each interval's per-bank register counts.
The compiler's renumbering search, the Fig. 6/16 histograms, and the
simulator's prefetch-latency precomputation all run this over thousands of
intervals × configurations, which is why it is the AOT-compiled artifact
the rust coordinator executes via PJRT.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the per-interval
histogram is expressed as a dense matmul — `bits[N,256] @ onehot[256,B]` —
so it maps onto the TPU MXU; working-set tiles stream through VMEM in
`(TILE_N, LANES)` blocks while the small one-hot bank matrix is pinned in
VMEM, and the occupancy-max / popcount reductions fuse into the same
kernel so the counts tile never round-trips to HBM.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU behaviour is estimated in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed geometry of the AOT artifact (rust pads batches to N_BATCH).
N_BATCH = 1024
MAX_REGS = 256
LANES = MAX_REGS // 32  # 8 × u32 per working set
TILE_N = 128


def _unpack_bits(ws_u32):
    """[n, LANES] u32 → [n, 256] f32 of 0/1 bits (little-endian lanes)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (ws_u32[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(ws_u32.shape[0], MAX_REGS).astype(jnp.float32)


def _kernel(ws_ref, onehot_ref, counts_ref, maxocc_ref, total_ref):
    """One TILE_N tile: unpack → MXU matmul → fused row reductions."""
    bits = _unpack_bits(ws_ref[...])  # [TILE_N, 256] in VMEM
    # MXU: per-bank occupancy counts.
    counts = jnp.dot(bits, onehot_ref[...], preferred_element_type=jnp.float32)
    counts_ref[...] = counts
    # Fused reductions: max occupancy and popcount per interval.
    maxocc_ref[...] = jnp.max(counts, axis=1)
    total_ref[...] = jnp.sum(counts, axis=1)


@functools.partial(jax.jit, static_argnames=("num_banks",))
def prefetch_eval_pallas(ws_u32, bank_onehot, num_banks=16):
    """Batched bank-occupancy evaluation via the Pallas kernel.

    Args:
      ws_u32: uint32[N, 8] working-set bit-vectors (N multiple of TILE_N).
      bank_onehot: float32[256, num_banks] one-hot bank assignment.
      num_banks: static bank count.

    Returns:
      (counts f32[N, num_banks], max_occ f32[N], total f32[N]).
    """
    n = ws_u32.shape[0]
    assert n % TILE_N == 0, f"batch {n} must be a multiple of {TILE_N}"
    grid = (n // TILE_N,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, LANES), lambda i: (i, 0)),
            # The one-hot matrix is small (256×B ≤ 16KB): pinned per tile.
            pl.BlockSpec((MAX_REGS, num_banks), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_N, num_banks), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, num_banks), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT execution; Mosaic lowering is TPU-only
    )(ws_u32, bank_onehot)
