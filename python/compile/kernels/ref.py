"""Pure-jnp oracle for the prefetch-evaluation kernel.

The correctness contract: `prefetch_eval_pallas(ws, onehot)` must agree
bit-exactly (values are small integers in f32) with this reference for all
inputs. pytest + hypothesis sweep shapes and contents against it.
"""

import jax.numpy as jnp

MAX_REGS = 256


def unpack_bits_ref(ws_u32):
    """[n, 8] u32 → [n, 256] f32 bits, little-endian lanes."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (ws_u32[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(ws_u32.shape[0], MAX_REGS).astype(jnp.float32)


def prefetch_eval_ref(ws_u32, bank_onehot):
    """Reference: counts, max occupancy, popcount."""
    bits = unpack_bits_ref(ws_u32)
    counts = bits @ bank_onehot
    return counts, jnp.max(counts, axis=1), jnp.sum(counts, axis=1)


def prefetch_latency_ref(max_occ, total, mrf_cycles, xbar_rate, xbar_latency):
    """Serialized prefetch latency model (mirrors model.py, used in tests):
    worst-bank serialization + narrow-crossbar transfer + traversal, zero
    for empty working sets."""
    busy = max_occ * mrf_cycles
    transfer = jnp.ceil(total / xbar_rate)
    lat = busy + transfer + xbar_latency
    return jnp.where(total > 0, lat, 0.0)
