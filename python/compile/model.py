"""L2 — JAX model: the prefetch evaluation graph lowered to the artifact.

Wraps the L1 Pallas kernel with the latency model the coordinator needs:
for every interval in the batch, the serialized MRF bank time (worst-bank
occupancy × access cycles), the narrow-crossbar transfer time, and the
conflict count (max occupancy − 1, the paper's §4 definition).

One jitted function → one HLO module → one PJRT executable; all shape
parameters are static so the rust side pads to `N_BATCH` and reuses the
compiled artifact for every workload × configuration sweep point.
"""

import jax
import jax.numpy as jnp

from .kernels.prefetch_eval import N_BATCH, prefetch_eval_pallas


def prefetch_eval_model(ws_u32, bank_onehot, mrf_cycles, xbar_rate, xbar_latency):
    """Full evaluation for a batch of prefetch bit-vectors.

    Args:
      ws_u32: uint32[N_BATCH, 8] working-set bit-vectors (zero-padded).
      bank_onehot: float32[256, 16] register→bank one-hot map.
      mrf_cycles: f32 scalar — MRF bank access occupancy (non-pipelined).
      xbar_rate: f32 scalar — refill-crossbar registers per cycle.
      xbar_latency: f32 scalar — crossbar traversal cycles.

    Returns a tuple:
      counts   f32[N_BATCH, 16] — per-bank register counts,
      conflicts f32[N_BATCH]    — max-occupancy − 1 (≥ 0; the §4 metric),
      latency  f32[N_BATCH]     — serialized prefetch cycles (0 if empty),
      total    f32[N_BATCH]     — working-set popcount.
    """
    counts, max_occ, total = prefetch_eval_pallas(ws_u32, bank_onehot, num_banks=16)
    conflicts = jnp.maximum(max_occ - 1.0, 0.0) * (total > 0)
    busy = max_occ * mrf_cycles
    transfer = jnp.ceil(total / xbar_rate)
    latency = jnp.where(total > 0, busy + transfer + xbar_latency, 0.0)
    return counts, conflicts, latency, total


def example_args():
    """Static example arguments for AOT lowering."""
    ws = jnp.zeros((N_BATCH, 8), dtype=jnp.uint32)
    onehot = jnp.zeros((256, 16), dtype=jnp.float32)
    scalar = jnp.float32(0.0)
    return ws, onehot, scalar, scalar, scalar
